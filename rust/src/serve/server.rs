//! The live service loop: listeners, per-connection threads, and the
//! single session thread that owns all scheduler state.
//!
//! ## Threading model
//!
//! The scheduler, job table, and session are **not** shared: the thread
//! that calls [`run`] owns them outright, and every mutation happens
//! there, between scheduling rounds. Connections talk to it through one
//! mpsc channel of [`SessionMsg`]s:
//!
//! * each listener runs an accept thread;
//! * each connection runs a **reader** thread (parses JSONL request
//!   lines into messages) and a **writer** thread (drains a bounded
//!   queue of outbound lines onto the socket);
//! * the session thread drains messages between rounds, applies
//!   commands at the current virtual minute, and fans events out.
//!
//! ## Backpressure
//!
//! Every connection's outbound queue is a `sync_channel` bounded at
//! [`ServeConfig::queue_cap`] lines. The session thread never blocks on
//! a slow consumer: a full queue drops the line, and the connection is
//! owed a `{"type":"lagged","dropped":N}` notice that is delivered as
//! soon as its queue has room again — before any newer event. Memory per
//! client is therefore strictly bounded; correctness is not, which is
//! why the notice is explicit and typed.
//!
//! ## Virtual time
//!
//! [`ServeConfig::tick_ms`] sets the wall-clock budget per simulated
//! minute (`0` = free-run). Rounds that fast-forward `n` minutes get an
//! `n`-minute budget, so the virtual/wall ratio holds across quiescent
//! spans; the budget is spent *waiting on the request channel*, so
//! commands arriving mid-budget are applied before the next round.
//!
//! ## Snapshots and shutdown
//!
//! With a snapshot directory configured, the session auto-snapshots
//! every [`ServeConfig::snapshot_every`] virtual minutes, always at a
//! round boundary. SIGTERM/SIGINT (or a `{"cmd":"shutdown"}` request)
//! stop the loop and write one final snapshot. A `kill -9` obviously
//! writes nothing — recovery then starts from the latest auto-snapshot
//! ([`super::snapshot::latest_in`]), which is exactly the failover drill
//! in EXPERIMENTS.md and the serve-smoke CI job.

use crate::sched::control::{EventSubscriber, SchedulerCommand, SchedulerEvent};
use crate::serve::snapshot;
use crate::serve::wire::{self, WireRequest};
use crate::sim::{SimResult, SimSession};
use crate::workload::source::ArrivalSource;
use crate::Minutes;
use anyhow::Context;
use std::cell::RefCell;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// How the service runs one session.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// The simulation to serve (must equal the snapshotted configuration
    /// when restoring).
    pub sim: crate::sim::SimConfig,
    /// TCP listen address (`host:port`), if any.
    pub tcp: Option<String>,
    /// Unix-domain socket path, if any (removed and re-bound on start).
    pub uds: Option<PathBuf>,
    /// Wall-clock milliseconds per virtual minute; `0` free-runs.
    pub tick_ms: u64,
    /// Per-connection outbound queue bound, in lines.
    pub queue_cap: usize,
    /// Where snapshots are written; `None` disables them.
    pub snapshot_dir: Option<PathBuf>,
    /// Auto-snapshot period in virtual minutes; `0` disables (final and
    /// requested snapshots still work).
    pub snapshot_every: Minutes,
    /// Restore from this snapshot file instead of starting at minute 0.
    pub restore_from: Option<PathBuf>,
    /// Exit as soon as the session drains instead of parking to wait for
    /// more wire traffic.
    pub exit_when_done: bool,
}

impl ServeConfig {
    /// Service defaults: no listeners, free-running, 1024-line client
    /// queues, no snapshots.
    pub fn new(sim: crate::sim::SimConfig) -> Self {
        ServeConfig {
            sim,
            tcp: None,
            uds: None,
            tick_ms: 0,
            queue_cap: 1024,
            snapshot_dir: None,
            snapshot_every: 0,
            restore_from: None,
            exit_when_done: false,
        }
    }
}

/// Counters the service kept while running.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Connections accepted over the lifetime of the service.
    pub connections: u64,
    /// Request lines handled (including malformed ones).
    pub requests: u64,
    /// Event lines enqueued to subscribers.
    pub events_sent: u64,
    /// Event lines dropped by backpressure (each drop is reported to its
    /// connection via a `lagged` notice).
    pub events_dropped: u64,
    /// Snapshots written (auto + requested + final).
    pub snapshots: u64,
}

/// Everything [`run`] hands back.
#[derive(Debug)]
pub struct ServeOutcome {
    /// The finished run, assembled exactly as a batch simulation would.
    pub result: SimResult,
    /// Service-layer counters.
    pub stats: ServeStats,
    /// True when SIGTERM/SIGINT (or a shutdown request) stopped the
    /// loop before the session drained.
    pub stopped: bool,
}

/// One line everyone greps for: does the final accounting balance?
/// `jobs_seen` counts every non-cancelled job the metrics sink observed,
/// so a lost job (or a double-retired one) breaks the equality.
pub fn conservation_line(res: &SimResult) -> String {
    let m = &res.metrics;
    let cancelled = m.cancelled.te + m.cancelled.be;
    let intact = m.jobs_seen == m.completed + m.unfinished;
    format!(
        "conservation {}: jobs_seen={} completed={} unfinished={} cancelled={}",
        if intact { "intact" } else { "VIOLATED" },
        m.jobs_seen,
        m.completed,
        m.unfinished,
        cancelled
    )
}

/// Set by the signal handler; polled by the session loop.
static STOP: AtomicBool = AtomicBool::new(false);

extern "C" fn note_stop(_sig: i32) {
    STOP.store(true, Ordering::SeqCst);
}

/// Route SIGTERM and SIGINT to the stop flag so the session loop can
/// write its final snapshot instead of dying mid-state.
fn install_stop_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, note_stop);
        signal(SIGTERM, note_stop);
    }
}

static NEXT_CONN: AtomicU64 = AtomicU64::new(1);

enum SessionMsg {
    Connected { conn: u64, tx: SyncSender<Arc<str>> },
    Request { conn: u64, line: String },
    Disconnected { conn: u64 },
}

/// One connection's outbound half, owned by the session thread.
struct ClientOut {
    conn: u64,
    tx: SyncSender<Arc<str>>,
    subscribed: bool,
    /// Events dropped since this client's queue last had room; a
    /// `lagged` notice for them is owed before any newer line.
    owed: u64,
}

/// The session thread's registry of live connections. Shared with the
/// event subscriber via `Rc<RefCell<…>>` — single-threaded by
/// construction, never locked.
struct FanOut {
    clients: Vec<ClientOut>,
    events_sent: u64,
    events_dropped: u64,
}

/// Try to hand `line` to one client without ever blocking: deliver any
/// owed `lagged` notice first, then the line; a full queue increments
/// the owed count instead of buffering.
fn offer(c: &mut ClientOut, line: Arc<str>, sent: &mut u64, dropped: &mut u64) {
    if c.owed > 0 {
        let notice: Arc<str> = Arc::from(wire::lagged_line(c.owed));
        match c.tx.try_send(notice) {
            Ok(()) => c.owed = 0,
            Err(TrySendError::Full(_)) => {
                c.owed += 1;
                *dropped += 1;
                return;
            }
            Err(TrySendError::Disconnected(_)) => return,
        }
    }
    match c.tx.try_send(line) {
        Ok(()) => *sent += 1,
        Err(TrySendError::Full(_)) => {
            c.owed += 1;
            *dropped += 1;
        }
        Err(TrySendError::Disconnected(_)) => {}
    }
}

impl FanOut {
    fn new() -> Self {
        FanOut { clients: Vec::new(), events_sent: 0, events_dropped: 0 }
    }

    fn event(&mut self, ev: &SchedulerEvent) {
        let FanOut { clients, events_sent, events_dropped } = self;
        if !clients.iter().any(|c| c.subscribed) {
            return;
        }
        let line: Arc<str> = Arc::from(crate::sched::control::event_jsonl_line(ev));
        for c in clients.iter_mut().filter(|c| c.subscribed) {
            offer(c, line.clone(), events_sent, events_dropped);
        }
    }

    fn respond(&mut self, conn: u64, line: String) {
        let FanOut { clients, events_sent, events_dropped } = self;
        if let Some(c) = clients.iter_mut().find(|c| c.conn == conn) {
            offer(c, Arc::from(line), events_sent, events_dropped);
        }
    }

    /// Deliver owed `lagged` notices to any client whose queue has
    /// drained. Without this, a client that lagged during a burst and
    /// then went quiet alongside the cluster would never learn it
    /// dropped anything — the notice must not wait for the next event.
    fn flush_owed(&mut self) {
        for c in self.clients.iter_mut() {
            if c.owed > 0 {
                let notice: Arc<str> = Arc::from(wire::lagged_line(c.owed));
                if c.tx.try_send(notice).is_ok() {
                    c.owed = 0;
                }
            }
        }
    }
}

/// Adapter: scheduler events → fan-out, as an [`EventSubscriber`].
struct FanOutSub(Rc<RefCell<FanOut>>);

impl EventSubscriber for FanOutSub {
    fn on_event(&mut self, ev: &SchedulerEvent) {
        self.0.borrow_mut().event(ev);
    }
}

/// Spawn the reader and writer threads for one accepted connection.
fn spawn_conn<R, W>(reader: R, writer: W, tx: Sender<SessionMsg>, queue_cap: usize)
where
    R: Read + Send + 'static,
    W: Write + Send + 'static,
{
    let conn = NEXT_CONN.fetch_add(1, Ordering::Relaxed);
    let (out_tx, out_rx) = mpsc::sync_channel::<Arc<str>>(queue_cap.max(1));
    thread::spawn(move || {
        let mut w = BufWriter::new(writer);
        while let Ok(line) = out_rx.recv() {
            let io = w
                .write_all(line.as_bytes())
                .and_then(|()| w.write_all(b"\n"))
                .and_then(|()| w.flush());
            if io.is_err() {
                return; // reader side reports the disconnect
            }
        }
    });
    if tx.send(SessionMsg::Connected { conn, tx: out_tx }).is_err() {
        return;
    }
    thread::spawn(move || {
        for line in BufReader::new(reader).lines() {
            match line {
                Ok(l) => {
                    if l.trim().is_empty() {
                        continue;
                    }
                    if tx.send(SessionMsg::Request { conn, line: l }).is_err() {
                        return;
                    }
                }
                Err(_) => break,
            }
        }
        let _ = tx.send(SessionMsg::Disconnected { conn });
    });
}

/// Bind and serve a TCP listener; returns the bound address (useful when
/// the config asked for port 0).
fn start_tcp(addr: &str, tx: Sender<SessionMsg>, queue_cap: usize) -> anyhow::Result<String> {
    let listener = std::net::TcpListener::bind(addr)
        .with_context(|| format!("binding tcp listener on {addr}"))?;
    let local = listener.local_addr()?.to_string();
    thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { break };
            let _ = stream.set_nodelay(true);
            let Ok(reader) = stream.try_clone() else { continue };
            spawn_conn(reader, stream, tx.clone(), queue_cap);
        }
    });
    Ok(local)
}

/// Bind and serve a Unix-domain socket listener, replacing any stale
/// socket file at the path.
#[cfg(unix)]
fn start_uds(path: &PathBuf, tx: Sender<SessionMsg>, queue_cap: usize) -> anyhow::Result<()> {
    if path.exists() {
        std::fs::remove_file(path)
            .with_context(|| format!("removing stale socket {}", path.display()))?;
    }
    let listener = std::os::unix::net::UnixListener::bind(path)
        .with_context(|| format!("binding unix socket {}", path.display()))?;
    thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { break };
            let Ok(reader) = stream.try_clone() else { continue };
            spawn_conn(reader, stream, tx.clone(), queue_cap);
        }
    });
    Ok(())
}

#[cfg(not(unix))]
fn start_uds(path: &PathBuf, _tx: Sender<SessionMsg>, _cap: usize) -> anyhow::Result<()> {
    anyhow::bail!("unix-domain sockets are not available on this platform: {}", path.display())
}

/// Mutable service state the message handler threads through.
struct ServerCtx {
    cfg: ServeConfig,
    fan: Rc<RefCell<FanOut>>,
    requests: u64,
    connections: u64,
    snapshots: u64,
    shutdown_requested: bool,
}

impl ServerCtx {
    /// Write a snapshot named for its label, minute, and a monotone
    /// sequence number (several snapshots can land on one minute).
    fn save_snapshot(&mut self, session: &SimSession, label: &str) -> anyhow::Result<PathBuf> {
        let dir = self
            .cfg
            .snapshot_dir
            .as_ref()
            .context("no --snapshot-dir configured")?;
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating snapshot dir {}", dir.display()))?;
        let path = dir.join(format!(
            "{label}-{:012}-{:06}.snap",
            session.now(),
            self.snapshots
        ));
        snapshot::save(&path, &snapshot::encode(session))?;
        self.snapshots += 1;
        Ok(path)
    }

    fn handle(&mut self, session: &mut SimSession, msg: SessionMsg) {
        match msg {
            SessionMsg::Connected { conn, tx } => {
                self.connections += 1;
                self.fan.borrow_mut().clients.push(ClientOut {
                    conn,
                    tx,
                    subscribed: false,
                    owed: 0,
                });
                self.fan
                    .borrow_mut()
                    .respond(conn, wire::hello_line(session.now()));
            }
            SessionMsg::Disconnected { conn } => {
                self.fan.borrow_mut().clients.retain(|c| c.conn != conn);
            }
            SessionMsg::Request { conn, line } => {
                self.requests += 1;
                match wire::parse_request(&line) {
                    Err(e) => self
                        .fan
                        .borrow_mut()
                        .respond(conn, wire::error_line(None, &format!("{e:#}"))),
                    Ok(WireRequest::Command { mut cmd, seq }) => {
                        if let SchedulerCommand::Submit(spec) = &mut cmd {
                            // "As soon as possible": live clients cannot
                            // know the virtual minute; a submit in the
                            // past lands on the current one.
                            if spec.submit < session.now() {
                                spec.submit = session.now();
                            }
                        }
                        if session.is_done() {
                            session.reopen();
                        }
                        session.command(cmd);
                        self.fan
                            .borrow_mut()
                            .respond(conn, wire::ack_line(seq, session.now()));
                    }
                    Ok(WireRequest::Subscribe { seq }) => {
                        let mut fan = self.fan.borrow_mut();
                        if let Some(c) = fan.clients.iter_mut().find(|c| c.conn == conn) {
                            c.subscribed = true;
                        }
                        fan.respond(conn, wire::ack_line(seq, session.now()));
                    }
                    Ok(WireRequest::Snapshot { seq }) => {
                        let line = match self.save_snapshot(session, "snap") {
                            Ok(path) => wire::snapshot_line(
                                seq,
                                session.now(),
                                &path.display().to_string(),
                            ),
                            Err(e) => wire::error_line(seq, &format!("{e:#}")),
                        };
                        self.fan.borrow_mut().respond(conn, line);
                    }
                    Ok(WireRequest::Ping { seq }) => self
                        .fan
                        .borrow_mut()
                        .respond(conn, wire::pong_line(seq, session.now())),
                    Ok(WireRequest::Shutdown { seq }) => {
                        self.shutdown_requested = true;
                        self.fan
                            .borrow_mut()
                            .respond(conn, wire::ack_line(seq, session.now()));
                    }
                }
            }
        }
    }

    fn stopping(&self) -> bool {
        self.shutdown_requested || STOP.load(Ordering::SeqCst)
    }
}

/// Serve one session until it drains (with `exit_when_done`), is told to
/// stop, or — without `exit_when_done` — forever, parking whenever the
/// cluster is idle. The calling thread owns every piece of scheduler
/// state; listeners and connections run on their own threads and talk to
/// it through messages.
pub fn run(cfg: ServeConfig, source: &mut dyn ArrivalSource) -> anyhow::Result<ServeOutcome> {
    install_stop_handlers();
    STOP.store(false, Ordering::SeqCst);
    let (tx, rx): (Sender<SessionMsg>, Receiver<SessionMsg>) = mpsc::channel();
    let fan = Rc::new(RefCell::new(FanOut::new()));
    if let Some(addr) = &cfg.tcp {
        let bound = start_tcp(addr, tx.clone(), cfg.queue_cap)?;
        eprintln!("serving tcp on {bound}");
    }
    if let Some(path) = &cfg.uds {
        start_uds(path, tx.clone(), cfg.queue_cap)?;
        eprintln!("serving unix socket at {}", path.display());
    }
    let subscribers: Vec<Box<dyn EventSubscriber>> = vec![Box::new(FanOutSub(fan.clone()))];
    let mut session = match &cfg.restore_from {
        Some(path) => {
            let bytes = snapshot::load(path)?;
            let s = snapshot::decode(&bytes, cfg.sim.clone(), subscribers, source)
                .with_context(|| format!("restoring snapshot {}", path.display()))?;
            eprintln!("restored snapshot {} at minute {}", path.display(), s.now());
            s
        }
        None => SimSession::new(cfg.sim.clone(), subscribers),
    };
    let every = cfg.snapshot_every;
    let mut next_auto = if every > 0 && cfg.snapshot_dir.is_some() {
        (session.now() / every + 1).saturating_mul(every)
    } else {
        Minutes::MAX
    };
    let mut ctx = ServerCtx {
        cfg,
        fan,
        requests: 0,
        connections: 0,
        snapshots: 0,
        shutdown_requested: false,
    };

    loop {
        while let Ok(msg) = rx.try_recv() {
            ctx.handle(&mut session, msg);
        }
        ctx.fan.borrow_mut().flush_owed();
        if ctx.stopping() {
            break;
        }
        if session.is_done() {
            if ctx.cfg.exit_when_done {
                break;
            }
            // Parked: virtual time freezes while the cluster is idle and
            // no work is pending; wake on traffic or the stop flag.
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(msg) => ctx.handle(&mut session, msg),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            continue;
        }
        if session.now() >= next_auto {
            let path = ctx.save_snapshot(&session, "auto")?;
            eprintln!("auto-snapshot at minute {}: {}", session.now(), path.display());
            while next_auto <= session.now() {
                next_auto = next_auto.saturating_add(every);
            }
        }
        let round_start = Instant::now();
        let before = session.now();
        session.round(source);
        if ctx.cfg.tick_ms > 0 {
            // Spend the wall budget for the minutes just simulated
            // waiting on the request channel, so commands arriving
            // mid-budget apply before the next round.
            let dt = session.now().saturating_sub(before).max(1);
            let deadline =
                round_start + Duration::from_millis(ctx.cfg.tick_ms.saturating_mul(dt));
            loop {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() || ctx.stopping() {
                    break;
                }
                match rx.recv_timeout(left) {
                    Ok(msg) => ctx.handle(&mut session, msg),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        }
    }

    let stopped = ctx.stopping();
    if stopped && ctx.cfg.snapshot_dir.is_some() {
        let path = ctx.save_snapshot(&session, "final")?;
        eprintln!("final snapshot at minute {}: {}", session.now(), path.display());
    }
    if let Some(path) = &ctx.cfg.uds {
        std::fs::remove_file(path).ok();
    }
    let result = session.finish(source);
    let fan = ctx.fan.borrow();
    Ok(ServeOutcome {
        result,
        stats: ServeStats {
            connections: ctx.connections,
            requests: ctx.requests,
            events_sent: fan.events_sent,
            events_dropped: fan.events_dropped,
            snapshots: ctx.snapshots,
        },
        stopped,
    })
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::sched::policy::PolicyKind;
    use crate::sim::SimConfig;
    use crate::util::json::Json;
    use crate::workload::source::WorkloadSource;
    use crate::workload::Workload;
    use std::os::unix::net::UnixStream;

    #[test]
    fn serves_submissions_events_and_shutdown_over_uds() {
        let sock = std::env::temp_dir().join(format!("fitgpp-serve-test-{}.sock", std::process::id()));
        let mut cfg = ServeConfig::new(SimConfig::new(ClusterSpec::tiny(1), PolicyKind::Fifo));
        cfg.sim.paranoid = true;
        cfg.uds = Some(sock.clone());
        cfg.queue_cap = 64;
        let server = thread::spawn(move || {
            let workload = Workload::new(vec![]);
            let mut source = WorkloadSource::new(&workload);
            run(cfg, &mut source).unwrap()
        });
        // Wait for the socket to appear.
        let mut tries = 0;
        let stream = loop {
            match UnixStream::connect(&sock) {
                Ok(s) => break s,
                Err(_) if tries < 200 => {
                    tries += 1;
                    thread::sleep(Duration::from_millis(10));
                }
                Err(e) => panic!("server socket never came up: {e}"),
            }
        };
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(Json::parse(&line).unwrap().get("type").as_str(), Some("hello"));
        writeln!(writer, r#"{{"cmd":"subscribe","seq":1}}"#).unwrap();
        for id in 0..3u32 {
            writeln!(
                writer,
                r#"{{"cmd":"submit","id":{id},"class":"BE","cpu":4,"ram_gb":16,"gpu":0,"exec_time":3,"seq":{}}}"#,
                10 + id
            )
            .unwrap();
        }
        writeln!(writer, r#"{{"cmd":"ping","seq":99}}"#).unwrap();
        let mut finished = 0;
        let mut saw_pong = false;
        while finished < 3 {
            line.clear();
            assert!(reader.read_line(&mut line).unwrap() > 0, "server closed early");
            let v = Json::parse(&line).unwrap();
            match v.get("type").as_str() {
                Some("finished") => finished += 1,
                Some("pong") => saw_pong = true,
                Some("error") => panic!("unexpected error: {line}"),
                _ => {}
            }
        }
        assert!(saw_pong, "ping must be answered");
        writeln!(writer, r#"{{"cmd":"shutdown"}}"#).unwrap();
        let outcome = server.join().unwrap();
        assert!(outcome.stopped);
        assert_eq!(outcome.stats.connections, 1);
        assert_eq!(outcome.result.records.len(), 3);
        assert_eq!(outcome.result.metrics.completed, 3);
        assert!(outcome.stats.events_sent > 0);
        assert_eq!(conservation_line(&outcome.result).split(':').next(), Some("conservation intact"));
    }

    #[test]
    fn slow_subscribers_get_lagged_notices_not_unbounded_buffers() {
        let sock = std::env::temp_dir().join(format!("fitgpp-lag-test-{}.sock", std::process::id()));
        let mut cfg = ServeConfig::new(SimConfig::new(ClusterSpec::tiny(2), PolicyKind::Fifo));
        cfg.uds = Some(sock.clone());
        cfg.queue_cap = 2; // tiny queue: overflow is the point
        let server = thread::spawn(move || {
            let workload = Workload::new(vec![]);
            let mut source = WorkloadSource::new(&workload);
            run(cfg, &mut source).unwrap()
        });
        let mut tries = 0;
        let stream = loop {
            match UnixStream::connect(&sock) {
                Ok(s) => break s,
                Err(_) if tries < 200 => {
                    tries += 1;
                    thread::sleep(Duration::from_millis(10));
                }
                Err(e) => panic!("server socket never came up: {e}"),
            }
        };
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writeln!(writer, r#"{{"cmd":"subscribe"}}"#).unwrap();
        // Submit a burst without reading anything: the 2-line queue must
        // overflow and the overflow must be reported, not buffered.
        for id in 0..40u32 {
            writeln!(
                writer,
                r#"{{"cmd":"submit","id":{id},"class":"BE","cpu":1,"ram_gb":1,"gpu":0,"exec_time":2}}"#
            )
            .unwrap();
        }
        // Give the session time to run the burst while we stay slow.
        thread::sleep(Duration::from_millis(400));
        let mut saw_lagged = false;
        let mut line = String::new();
        while reader.read_line(&mut line).unwrap() > 0 {
            if Json::parse(&line).unwrap().get("type").as_str() == Some("lagged") {
                saw_lagged = true;
                writeln!(writer, r#"{{"cmd":"shutdown"}}"#).unwrap();
            }
            line.clear();
        }
        let outcome = server.join().unwrap();
        assert!(saw_lagged, "overflow must surface as a lagged notice");
        assert!(outcome.stats.events_dropped > 0);
        assert_eq!(outcome.result.metrics.completed, 40);
    }
}
