//! The live service loop: listeners, per-connection threads, and the
//! single session thread that owns all scheduler state.
//!
//! ## Threading model
//!
//! The scheduler, job table, and session are **not** shared: the thread
//! that calls [`run`] owns them outright, and every mutation happens
//! there, between scheduling rounds. Connections talk to it through one
//! mpsc channel of [`SessionMsg`]s:
//!
//! * each listener runs an accept thread;
//! * each connection runs a **reader** thread (parses JSONL request
//!   lines into messages) and a **writer** thread (drains a bounded
//!   queue of outbound messages onto the socket);
//! * the session thread drains **all** pending messages between rounds,
//!   applies commands at the current virtual minute, and fans events
//!   out.
//!
//! ## The wire hot path
//!
//! Outbound lines are encoded **once**, directly into a reusable scratch
//! buffer (`JsonLineEncoder` for events, `ResponseEncoder` for
//! responses — no per-event JSON value tree), shared to all subscribers
//! as one `Arc<str>`. Lines staged during one session-loop iteration are
//! coalesced into per-client **batches**: one channel send per batch (at
//! most [`ServeConfig::batch_max`] lines each) instead of one per line,
//! and the writer thread drains everything queued, writes it through one
//! `BufWriter`, and flushes **once** per drain instead of once per line.
//! `cargo bench --bench serve` measures the result (commands/sec,
//! events/sec, ack p50/p99) and pins the encode path allocation-free.
//!
//! ## Backpressure
//!
//! Every connection's outbound queue is bounded at
//! [`ServeConfig::queue_cap`] *lines* (tracked exactly, across batches,
//! via a shared in-flight counter the writer thread decrements). The
//! session thread never blocks on a slow consumer: lines beyond the
//! budget are dropped, and the connection is owed a
//! `{"type":"lagged","dropped":N}` notice that is delivered as soon as
//! its queue has room again — before any newer line. Memory per client
//! is therefore strictly bounded; correctness is not, which is why the
//! notice is explicit and typed.
//!
//! ## Virtual time
//!
//! [`ServeConfig::tick_ms`] sets the wall-clock budget per simulated
//! minute (`0` = free-run). Rounds that fast-forward `n` minutes get an
//! `n`-minute budget, so the virtual/wall ratio holds across quiescent
//! spans; the budget is spent *waiting on the request channel*, so
//! commands arriving mid-budget are applied before the next round. When
//! the session drains and no work is pending, the loop **blocks** on the
//! channel (no polling): an idle server burns ~0 CPU, and a stop signal
//! wakes it through a self-pipe waker thread.
//!
//! ## Snapshots and shutdown
//!
//! With a snapshot directory configured, the session auto-snapshots
//! every [`ServeConfig::snapshot_every`] virtual minutes, always at a
//! round boundary. The session thread only does the fast in-memory
//! encode; the blocking tmp+rename disk write happens on a background
//! [`snapshot::SnapshotWriter`] thread, and the time the session thread
//! *did* spend on snapshot work is reported as
//! [`ServeStats::snapshot_stall_ms`]. SIGTERM/SIGINT (or a
//! `{"cmd":"shutdown"}` request) stop the loop and write one final
//! snapshot; [`run`] returns only after every queued snapshot is durable
//! on disk. A `kill -9` obviously writes nothing — a write interrupted
//! mid-flight leaves at worst a `*.snap.tmp` orphan that the restore
//! path ignores, and recovery starts from the latest complete
//! auto-snapshot ([`super::snapshot::latest_in`]), which is exactly the
//! failover drill in EXPERIMENTS.md and the serve-smoke CI job.

use crate::sched::control::{
    EventSubscriber, JsonLineEncoder, SchedulerCommand, SchedulerEvent,
};
use crate::serve::snapshot::{self, SnapshotWriter};
use crate::serve::wire::{self, ResponseEncoder, WireRequest};
use crate::sim::{SimResult, SimSession};
use crate::workload::source::ArrivalSource;
use crate::Minutes;
use anyhow::Context;
use std::cell::RefCell;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicI32, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, Once};
use std::thread;
use std::time::{Duration, Instant};

/// How the service runs one session.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// The simulation to serve (must equal the snapshotted configuration
    /// when restoring).
    pub sim: crate::sim::SimConfig,
    /// TCP listen address (`host:port`), if any.
    pub tcp: Option<String>,
    /// Unix-domain socket path, if any (removed and re-bound on start).
    pub uds: Option<PathBuf>,
    /// Wall-clock milliseconds per virtual minute; `0` free-runs.
    pub tick_ms: u64,
    /// Per-connection outbound queue bound, in lines.
    pub queue_cap: usize,
    /// Most lines coalesced into one outbound channel message / socket
    /// write burst. `1` degenerates to the per-line path (useful for the
    /// bench sweep); larger values amortize wakeups and flushes.
    pub batch_max: usize,
    /// Where snapshots are written; `None` disables them.
    pub snapshot_dir: Option<PathBuf>,
    /// Auto-snapshot period in virtual minutes; `0` disables (final and
    /// requested snapshots still work).
    pub snapshot_every: Minutes,
    /// Restore from this snapshot file instead of starting at minute 0.
    pub restore_from: Option<PathBuf>,
    /// Exit as soon as the session drains instead of parking to wait for
    /// more wire traffic.
    pub exit_when_done: bool,
}

impl ServeConfig {
    /// Service defaults: no listeners, free-running, 1024-line client
    /// queues, 256-line fan-out batches, no snapshots.
    pub fn new(sim: crate::sim::SimConfig) -> Self {
        ServeConfig {
            sim,
            tcp: None,
            uds: None,
            tick_ms: 0,
            queue_cap: 1024,
            batch_max: 256,
            snapshot_dir: None,
            snapshot_every: 0,
            restore_from: None,
            exit_when_done: false,
        }
    }
}

/// Counters the service kept while running.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Connections accepted over the lifetime of the service.
    pub connections: u64,
    /// Request lines handled (including malformed ones).
    pub requests: u64,
    /// Event lines enqueued to subscribers.
    pub events_sent: u64,
    /// Event lines dropped by backpressure (each drop is reported to its
    /// connection via a `lagged` notice).
    pub events_dropped: u64,
    /// Snapshots written (auto + requested + final).
    pub snapshots: u64,
    /// Total wall milliseconds the session thread spent on snapshot work
    /// (in-memory encode + handoff; disk writes happen on the background
    /// writer thread and do not stall the wire).
    pub snapshot_stall_ms: f64,
}

/// Everything [`run`] hands back.
#[derive(Debug)]
pub struct ServeOutcome {
    /// The finished run, assembled exactly as a batch simulation would.
    pub result: SimResult,
    /// Service-layer counters.
    pub stats: ServeStats,
    /// True when SIGTERM/SIGINT (or a shutdown request) stopped the
    /// loop before the session drained.
    pub stopped: bool,
}

/// One line everyone greps for: does the final accounting balance?
/// `jobs_seen` counts every non-cancelled job the metrics sink observed,
/// so a lost job (or a double-retired one) breaks the equality.
pub fn conservation_line(res: &SimResult) -> String {
    let m = &res.metrics;
    let cancelled = m.cancelled.te + m.cancelled.be;
    let intact = m.jobs_seen == m.completed + m.unfinished;
    format!(
        "conservation {}: jobs_seen={} completed={} unfinished={} cancelled={}",
        if intact { "intact" } else { "VIOLATED" },
        m.jobs_seen,
        m.completed,
        m.unfinished,
        cancelled
    )
}

/// Set by the signal handler; checked by the session loop.
static STOP: AtomicBool = AtomicBool::new(false);

/// Write end of the self-pipe the signal handler pokes so a session
/// parked in a blocking `recv` wakes immediately (`-1` = not installed).
static STOP_WAKE_FD: AtomicI32 = AtomicI32::new(-1);

/// The session channel the waker thread forwards stop wake-ups into.
/// Re-pointed by each [`run`]; the waker thread itself is spawned once
/// per process. (Stop signals are process-wide — `STOP` already stops
/// every live session — so one waker suffices.)
static WAKER_TX: Mutex<Option<Sender<SessionMsg>>> = Mutex::new(None);
static WAKER_INIT: Once = Once::new();

#[cfg(unix)]
fn poke_stop_pipe() {
    extern "C" {
        fn write(fd: i32, buf: *const u8, n: usize) -> isize;
    }
    let fd = STOP_WAKE_FD.load(Ordering::SeqCst);
    if fd >= 0 {
        let byte = [1u8];
        unsafe {
            let _ = write(fd, byte.as_ptr(), 1);
        }
    }
}

#[cfg(not(unix))]
fn poke_stop_pipe() {}

extern "C" fn note_stop(_sig: i32) {
    STOP.store(true, Ordering::SeqCst);
    // `write(2)` is async-signal-safe; everything else (the channel
    // send) happens on the waker thread.
    poke_stop_pipe();
}

/// Route SIGTERM and SIGINT to the stop flag so the session loop can
/// write its final snapshot instead of dying mid-state.
fn install_stop_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, note_stop);
        signal(SIGTERM, note_stop);
    }
}

/// Point the stop waker at this session's channel and, once per process,
/// build the self-pipe and spawn the thread that turns a signal-handler
/// pipe write into a [`SessionMsg::Wake`]. This is what lets the parked
/// session block on `recv` outright instead of polling the stop flag.
#[cfg(unix)]
fn install_stop_waker(tx: Sender<SessionMsg>) {
    *WAKER_TX.lock().unwrap() = Some(tx);
    WAKER_INIT.call_once(|| {
        extern "C" {
            fn pipe(fds: *mut i32) -> i32;
        }
        let mut fds = [0i32; 2];
        if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
            return; // no waker: stop still lands at the next message
        }
        let rfd = fds[0];
        STOP_WAKE_FD.store(fds[1], Ordering::SeqCst);
        thread::spawn(move || {
            extern "C" {
                fn read(fd: i32, buf: *mut u8, n: usize) -> isize;
            }
            let mut byte = [0u8; 1];
            loop {
                let n = unsafe { read(rfd, byte.as_mut_ptr(), 1) };
                if n <= 0 {
                    return;
                }
                if let Some(tx) = WAKER_TX.lock().unwrap().as_ref() {
                    let _ = tx.send(SessionMsg::Wake);
                }
            }
        });
    });
}

#[cfg(not(unix))]
fn install_stop_waker(_tx: Sender<SessionMsg>) {}

/// Block until the next message while the session is drained and idle.
/// On unix the stop waker guarantees a signal still wakes us; elsewhere
/// fall back to polling the stop flag.
#[cfg(unix)]
fn park_recv(rx: &Receiver<SessionMsg>) -> Option<SessionMsg> {
    rx.recv().ok()
}

#[cfg(not(unix))]
fn park_recv(rx: &Receiver<SessionMsg>) -> Option<SessionMsg> {
    loop {
        if STOP.load(Ordering::SeqCst) {
            return None;
        }
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(msg) => return Some(msg),
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => return None,
        }
    }
}

static NEXT_CONN: AtomicU64 = AtomicU64::new(1);

/// One outbound channel message: a single line or a coalesced batch of
/// lines (each at most [`ServeConfig::batch_max`] long).
enum OutMsg {
    Line(Arc<str>),
    Batch(Arc<[Arc<str>]>),
}

impl OutMsg {
    fn lines(&self) -> u64 {
        match self {
            OutMsg::Line(_) => 1,
            OutMsg::Batch(b) => b.len() as u64,
        }
    }
}

enum SessionMsg {
    Connected {
        conn: u64,
        tx: SyncSender<OutMsg>,
        /// Lines queued but not yet written to the socket; shared with
        /// the writer thread so the session can enforce
        /// [`ServeConfig::queue_cap`] in *lines* across batches.
        inflight: Arc<AtomicU64>,
    },
    Request {
        conn: u64,
        line: String,
    },
    Disconnected {
        conn: u64,
    },
    /// A stop signal landed; wakes a parked session so it notices.
    Wake,
}

/// One connection's outbound half, owned by the session thread.
struct ClientOut {
    conn: u64,
    tx: SyncSender<OutMsg>,
    inflight: Arc<AtomicU64>,
    subscribed: bool,
    /// Events dropped since this client's queue last had room; a
    /// `lagged` notice for them is owed before any newer line.
    owed: u64,
    /// Lines staged during the current session-loop iteration, sent as
    /// coalesced batches at the next flush.
    pending: Vec<Arc<str>>,
}

/// The session thread's registry of live connections. Shared with the
/// event subscriber via `Rc<RefCell<…>>` — single-threaded by
/// construction, never locked. Owns the reusable direct encoders, so
/// steady-state event/response serialization allocates nothing beyond
/// the one shared `Arc<str>` per line.
struct FanOut {
    clients: Vec<ClientOut>,
    enc: JsonLineEncoder,
    resp: ResponseEncoder,
    queue_cap: usize,
    batch_max: usize,
    events_sent: u64,
    events_dropped: u64,
}

/// Flush one client's staged lines without ever blocking: deliver any
/// owed `lagged` notice first, then the staged lines in batches, each
/// within the remaining line budget (`queue_cap` minus lines already
/// queued). Lines beyond the budget are dropped and owed.
fn flush_client(
    c: &mut ClientOut,
    resp: &mut ResponseEncoder,
    queue_cap: usize,
    batch_max: usize,
    sent: &mut u64,
    dropped: &mut u64,
) {
    if c.pending.is_empty() && c.owed == 0 {
        return;
    }
    let queued = c.inflight.load(Ordering::Acquire) as usize;
    let mut budget = queue_cap.saturating_sub(queued);
    if c.owed > 0 {
        if budget == 0 {
            // Still no room: everything staged this iteration drops too,
            // folded into the notice the client is owed. Nothing newer
            // than the gap is ever delivered before the notice.
            let n = c.pending.len() as u64;
            c.owed += n;
            *dropped += n;
            c.pending.clear();
            return;
        }
        let notice: Arc<str> = Arc::from(resp.lagged(c.owed));
        c.inflight.fetch_add(1, Ordering::AcqRel);
        match c.tx.try_send(OutMsg::Line(notice)) {
            Ok(()) => {
                c.owed = 0;
                budget -= 1;
            }
            Err(_) => {
                c.inflight.fetch_sub(1, Ordering::AcqRel);
                c.pending.clear();
                return;
            }
        }
    }
    let mut idx = 0;
    while idx < c.pending.len() {
        if budget == 0 {
            let rest = (c.pending.len() - idx) as u64;
            c.owed += rest;
            *dropped += rest;
            break;
        }
        let chunk = batch_max.max(1).min(budget).min(c.pending.len() - idx);
        let end = idx + chunk;
        let msg = if chunk == 1 {
            OutMsg::Line(c.pending[idx].clone())
        } else {
            OutMsg::Batch(c.pending[idx..end].iter().cloned().collect())
        };
        c.inflight.fetch_add(chunk as u64, Ordering::AcqRel);
        match c.tx.try_send(msg) {
            Ok(()) => {
                *sent += chunk as u64;
                budget -= chunk;
                idx = end;
            }
            Err(TrySendError::Full(_)) => {
                // Unreachable under the line accounting (messages ≤
                // lines ≤ cap), but never block or lose count if it
                // happens anyway.
                c.inflight.fetch_sub(chunk as u64, Ordering::AcqRel);
                let rest = (c.pending.len() - idx) as u64;
                c.owed += rest;
                *dropped += rest;
                break;
            }
            Err(TrySendError::Disconnected(_)) => {
                c.inflight.fetch_sub(chunk as u64, Ordering::AcqRel);
                break;
            }
        }
    }
    c.pending.clear();
}

impl FanOut {
    fn new(queue_cap: usize, batch_max: usize) -> Self {
        FanOut {
            clients: Vec::new(),
            enc: JsonLineEncoder::new(),
            resp: ResponseEncoder::new(),
            queue_cap,
            batch_max,
            events_sent: 0,
            events_dropped: 0,
        }
    }

    /// Encode an event once (directly, no value tree) and stage the
    /// shared line for every subscriber.
    fn event(&mut self, ev: &SchedulerEvent) {
        let FanOut { clients, enc, .. } = self;
        if !clients.iter().any(|c| c.subscribed) {
            return;
        }
        let line: Arc<str> = Arc::from(enc.event(ev));
        for c in clients.iter_mut().filter(|c| c.subscribed) {
            c.pending.push(line.clone());
        }
    }

    /// Stage one response line for a single connection.
    fn push_line(&mut self, conn: u64, line: Arc<str>) {
        if let Some(c) = self.clients.iter_mut().find(|c| c.conn == conn) {
            c.pending.push(line);
        }
    }

    fn hello(&mut self, conn: u64, now: Minutes) {
        let line: Arc<str> = Arc::from(self.resp.hello(now));
        self.push_line(conn, line);
    }

    fn ack(&mut self, conn: u64, seq: Option<u64>, now: Minutes) {
        let line: Arc<str> = Arc::from(self.resp.ack(seq, now));
        self.push_line(conn, line);
    }

    fn error(&mut self, conn: u64, seq: Option<u64>, message: &str) {
        let line: Arc<str> = Arc::from(self.resp.error(seq, message));
        self.push_line(conn, line);
    }

    fn pong(&mut self, conn: u64, seq: Option<u64>, now: Minutes) {
        let line: Arc<str> = Arc::from(self.resp.pong(seq, now));
        self.push_line(conn, line);
    }

    fn snapshot_done(&mut self, conn: u64, seq: Option<u64>, minute: Minutes, path: &str) {
        let line: Arc<str> = Arc::from(self.resp.snapshot(seq, minute, path));
        self.push_line(conn, line);
    }

    /// Send everything staged since the last flush as per-client batches
    /// (one channel message per [`ServeConfig::batch_max`] lines), and
    /// deliver owed `lagged` notices to any client whose queue has
    /// drained. Without the latter, a client that lagged during a burst
    /// and then went quiet alongside the cluster would never learn it
    /// dropped anything — the notice must not wait for the next event.
    fn flush(&mut self) {
        let FanOut {
            clients,
            resp,
            queue_cap,
            batch_max,
            events_sent,
            events_dropped,
            ..
        } = self;
        for c in clients.iter_mut() {
            flush_client(c, resp, *queue_cap, *batch_max, events_sent, events_dropped);
        }
    }

    /// Last-chance delivery of owed `lagged` notices at shutdown.
    /// Without this, a client that was still draining its queue when the
    /// server stopped would never learn about its final gap and its drop
    /// accounting would not balance. The line budget is irrelevant here
    /// (the stream is over; nothing can follow the notice), so this
    /// retries briefly past it — but never hangs shutdown on a consumer
    /// that has stopped reading.
    fn flush_owed_final(&mut self) {
        let FanOut { clients, resp, .. } = self;
        for c in clients.iter_mut() {
            if c.owed == 0 {
                continue;
            }
            let mut notice: Arc<str> = Arc::from(resp.lagged(c.owed));
            for _ in 0..25 {
                c.inflight.fetch_add(1, Ordering::AcqRel);
                match c.tx.try_send(OutMsg::Line(notice)) {
                    Ok(()) => {
                        c.owed = 0;
                        break;
                    }
                    Err(TrySendError::Full(msg)) => {
                        c.inflight.fetch_sub(1, Ordering::AcqRel);
                        notice = match msg {
                            OutMsg::Line(line) => line,
                            OutMsg::Batch(_) => unreachable!("sent a line"),
                        };
                        thread::sleep(Duration::from_millis(10));
                    }
                    Err(TrySendError::Disconnected(_)) => {
                        c.inflight.fetch_sub(1, Ordering::AcqRel);
                        break;
                    }
                }
            }
        }
    }
}

/// Adapter: scheduler events → fan-out, as an [`EventSubscriber`].
struct FanOutSub(Rc<RefCell<FanOut>>);

impl EventSubscriber for FanOutSub {
    fn on_event(&mut self, ev: &SchedulerEvent) {
        self.0.borrow_mut().event(ev);
    }
}

fn write_msg<W: Write>(w: &mut W, msg: &OutMsg) -> std::io::Result<()> {
    match msg {
        OutMsg::Line(line) => {
            w.write_all(line.as_bytes())?;
            w.write_all(b"\n")
        }
        OutMsg::Batch(lines) => {
            for line in lines.iter() {
                w.write_all(line.as_bytes())?;
                w.write_all(b"\n")?;
            }
            Ok(())
        }
    }
}

/// Spawn the reader and writer threads for one accepted connection.
fn spawn_conn<R, W>(reader: R, writer: W, tx: Sender<SessionMsg>, queue_cap: usize)
where
    R: Read + Send + 'static,
    W: Write + Send + 'static,
{
    let conn = NEXT_CONN.fetch_add(1, Ordering::Relaxed);
    // Message count can never exceed line count, so `queue_cap` slots
    // are enough for the line-budgeted sender never to see `Full`.
    let (out_tx, out_rx) = mpsc::sync_channel::<OutMsg>(queue_cap.max(1));
    let inflight = Arc::new(AtomicU64::new(0));
    let inflight_w = inflight.clone();
    thread::spawn(move || {
        let mut w = BufWriter::new(writer);
        // Block for the first message, then drain everything already
        // queued and flush once per drain — not once per line.
        'conn: while let Ok(first) = out_rx.recv() {
            let mut next = Some(first);
            loop {
                let msg = match next.take() {
                    Some(m) => m,
                    None => match out_rx.try_recv() {
                        Ok(m) => m,
                        Err(_) => break,
                    },
                };
                let n = msg.lines();
                let io = write_msg(&mut w, &msg);
                inflight_w.fetch_sub(n, Ordering::AcqRel);
                if io.is_err() {
                    break 'conn; // reader side reports the disconnect
                }
            }
            if w.flush().is_err() {
                break;
            }
        }
    });
    if tx
        .send(SessionMsg::Connected { conn, tx: out_tx, inflight })
        .is_err()
    {
        return;
    }
    thread::spawn(move || {
        for line in BufReader::new(reader).lines() {
            match line {
                Ok(l) => {
                    if l.trim().is_empty() {
                        continue;
                    }
                    if tx.send(SessionMsg::Request { conn, line: l }).is_err() {
                        return;
                    }
                }
                Err(_) => break,
            }
        }
        let _ = tx.send(SessionMsg::Disconnected { conn });
    });
}

/// Bind and serve a TCP listener; returns the bound address (useful when
/// the config asked for port 0).
fn start_tcp(addr: &str, tx: Sender<SessionMsg>, queue_cap: usize) -> anyhow::Result<String> {
    let listener = std::net::TcpListener::bind(addr)
        .with_context(|| format!("binding tcp listener on {addr}"))?;
    let local = listener.local_addr()?.to_string();
    thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { break };
            let _ = stream.set_nodelay(true);
            let Ok(reader) = stream.try_clone() else { continue };
            spawn_conn(reader, stream, tx.clone(), queue_cap);
        }
    });
    Ok(local)
}

/// Bind and serve a Unix-domain socket listener, replacing any stale
/// socket file at the path.
#[cfg(unix)]
fn start_uds(path: &PathBuf, tx: Sender<SessionMsg>, queue_cap: usize) -> anyhow::Result<()> {
    if path.exists() {
        std::fs::remove_file(path)
            .with_context(|| format!("removing stale socket {}", path.display()))?;
    }
    let listener = std::os::unix::net::UnixListener::bind(path)
        .with_context(|| format!("binding unix socket {}", path.display()))?;
    thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { break };
            let Ok(reader) = stream.try_clone() else { continue };
            spawn_conn(reader, stream, tx.clone(), queue_cap);
        }
    });
    Ok(())
}

#[cfg(not(unix))]
fn start_uds(path: &PathBuf, _tx: Sender<SessionMsg>, _cap: usize) -> anyhow::Result<()> {
    anyhow::bail!("unix-domain sockets are not available on this platform: {}", path.display())
}

/// Mutable service state the message handler threads through.
struct ServerCtx {
    cfg: ServeConfig,
    fan: Rc<RefCell<FanOut>>,
    /// Lazily spawned background disk writer for auto/final snapshots.
    snap_writer: Option<SnapshotWriter>,
    requests: u64,
    connections: u64,
    snapshots: u64,
    /// Session-thread milliseconds spent on snapshot work (encode +
    /// handoff for async writes; the full save for requested ones).
    snapshot_stall_ms: f64,
    shutdown_requested: bool,
}

impl ServerCtx {
    /// The path a snapshot will be written to, named for its label,
    /// minute, and a monotone sequence number (several snapshots can
    /// land on one minute). Creates the directory and bumps the counter.
    fn snapshot_target(&mut self, session: &SimSession, label: &str) -> anyhow::Result<PathBuf> {
        let dir = self
            .cfg
            .snapshot_dir
            .as_ref()
            .context("no --snapshot-dir configured")?;
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating snapshot dir {}", dir.display()))?;
        let path = dir.join(format!(
            "{label}-{:012}-{:06}.snap",
            session.now(),
            self.snapshots
        ));
        self.snapshots += 1;
        Ok(path)
    }

    /// Write a snapshot synchronously (client-requested snapshots: the
    /// response names a file that must already be durable).
    fn save_snapshot_sync(&mut self, session: &SimSession, label: &str) -> anyhow::Result<PathBuf> {
        let t0 = Instant::now();
        let path = self.snapshot_target(session, label)?;
        let result = snapshot::save(&path, &snapshot::encode(session));
        self.snapshot_stall_ms += t0.elapsed().as_secs_f64() * 1e3;
        result?;
        Ok(path)
    }

    /// Encode a snapshot in memory and hand it to the background writer
    /// (auto/final snapshots: the session thread never waits on disk).
    fn save_snapshot_async(&mut self, session: &SimSession, label: &str) -> anyhow::Result<PathBuf> {
        let t0 = Instant::now();
        let path = self.snapshot_target(session, label)?;
        let bytes = snapshot::encode(session);
        let queued = self
            .snap_writer
            .get_or_insert_with(SnapshotWriter::spawn)
            .enqueue(path.clone(), bytes);
        self.snapshot_stall_ms += t0.elapsed().as_secs_f64() * 1e3;
        // A dead writer thread means a disk write already failed; its
        // error surfaces when the writer is finished at shutdown.
        anyhow::ensure!(queued, "snapshot writer thread is gone (earlier write failed?)");
        Ok(path)
    }

    fn handle(&mut self, session: &mut SimSession, msg: SessionMsg) {
        match msg {
            SessionMsg::Wake => {}
            SessionMsg::Connected { conn, tx, inflight } => {
                self.connections += 1;
                let mut fan = self.fan.borrow_mut();
                fan.clients.push(ClientOut {
                    conn,
                    tx,
                    inflight,
                    subscribed: false,
                    owed: 0,
                    pending: Vec::new(),
                });
                fan.hello(conn, session.now());
            }
            SessionMsg::Disconnected { conn } => {
                self.fan.borrow_mut().clients.retain(|c| c.conn != conn);
            }
            SessionMsg::Request { conn, line } => {
                self.requests += 1;
                match wire::parse_request(&line) {
                    Err(e) => self.fan.borrow_mut().error(conn, None, &format!("{e:#}")),
                    Ok(WireRequest::Command { mut cmd, seq }) => {
                        if let SchedulerCommand::Submit(spec) = &mut cmd {
                            // "As soon as possible": live clients cannot
                            // know the virtual minute; a submit in the
                            // past lands on the current one.
                            if spec.submit < session.now() {
                                spec.submit = session.now();
                            }
                        }
                        if session.is_done() {
                            session.reopen();
                        }
                        session.command(cmd);
                        self.fan.borrow_mut().ack(conn, seq, session.now());
                    }
                    Ok(WireRequest::Subscribe { seq }) => {
                        let mut fan = self.fan.borrow_mut();
                        if let Some(c) = fan.clients.iter_mut().find(|c| c.conn == conn) {
                            c.subscribed = true;
                        }
                        fan.ack(conn, seq, session.now());
                    }
                    Ok(WireRequest::Snapshot { seq }) => {
                        match self.save_snapshot_sync(session, "snap") {
                            Ok(path) => self.fan.borrow_mut().snapshot_done(
                                conn,
                                seq,
                                session.now(),
                                &path.display().to_string(),
                            ),
                            Err(e) => self.fan.borrow_mut().error(conn, seq, &format!("{e:#}")),
                        }
                    }
                    Ok(WireRequest::Ping { seq }) => {
                        self.fan.borrow_mut().pong(conn, seq, session.now())
                    }
                    Ok(WireRequest::Shutdown { seq }) => {
                        self.shutdown_requested = true;
                        self.fan.borrow_mut().ack(conn, seq, session.now());
                    }
                }
            }
        }
    }

    fn stopping(&self) -> bool {
        self.shutdown_requested || STOP.load(Ordering::SeqCst)
    }
}

/// Serve one session until it drains (with `exit_when_done`), is told to
/// stop, or — without `exit_when_done` — forever, parking whenever the
/// cluster is idle. The calling thread owns every piece of scheduler
/// state; listeners and connections run on their own threads and talk to
/// it through messages.
pub fn run(cfg: ServeConfig, source: &mut dyn ArrivalSource) -> anyhow::Result<ServeOutcome> {
    install_stop_handlers();
    STOP.store(false, Ordering::SeqCst);
    let (tx, rx): (Sender<SessionMsg>, Receiver<SessionMsg>) = mpsc::channel();
    install_stop_waker(tx.clone());
    let fan = Rc::new(RefCell::new(FanOut::new(cfg.queue_cap, cfg.batch_max)));
    if let Some(addr) = &cfg.tcp {
        let bound = start_tcp(addr, tx.clone(), cfg.queue_cap)?;
        eprintln!("serving tcp on {bound}");
    }
    if let Some(path) = &cfg.uds {
        start_uds(path, tx.clone(), cfg.queue_cap)?;
        eprintln!("serving unix socket at {}", path.display());
    }
    let subscribers: Vec<Box<dyn EventSubscriber>> = vec![Box::new(FanOutSub(fan.clone()))];
    let mut session = match &cfg.restore_from {
        Some(path) => {
            let bytes = snapshot::load(path)?;
            let s = snapshot::decode(&bytes, cfg.sim.clone(), subscribers, source)
                .with_context(|| format!("restoring snapshot {}", path.display()))?;
            eprintln!("restored snapshot {} at minute {}", path.display(), s.now());
            s
        }
        None => SimSession::new(cfg.sim.clone(), subscribers),
    };
    let every = cfg.snapshot_every;
    let mut next_auto = if every > 0 && cfg.snapshot_dir.is_some() {
        (session.now() / every + 1).saturating_mul(every)
    } else {
        Minutes::MAX
    };
    let mut ctx = ServerCtx {
        cfg,
        fan,
        snap_writer: None,
        requests: 0,
        connections: 0,
        snapshots: 0,
        snapshot_stall_ms: 0.0,
        shutdown_requested: false,
    };

    let mut loop_err: Option<anyhow::Error> = None;
    loop {
        // Drain and apply *everything* queued — commands, connects,
        // disconnects — then flush the staged responses/events as
        // per-client batches.
        while let Ok(msg) = rx.try_recv() {
            ctx.handle(&mut session, msg);
        }
        ctx.fan.borrow_mut().flush();
        if ctx.stopping() {
            break;
        }
        if session.is_done() {
            if ctx.cfg.exit_when_done {
                break;
            }
            // Parked: virtual time freezes while the cluster is idle and
            // no work is pending. Block outright — no polling — until
            // traffic arrives or the stop waker pokes the channel.
            match park_recv(&rx) {
                Some(msg) => ctx.handle(&mut session, msg),
                None => break,
            }
            continue;
        }
        if session.now() >= next_auto {
            match ctx.save_snapshot_async(&session, "auto") {
                Ok(path) => {
                    eprintln!("auto-snapshot at minute {}: {}", session.now(), path.display());
                }
                Err(e) => {
                    loop_err = Some(e);
                    break;
                }
            }
            while next_auto <= session.now() {
                next_auto = next_auto.saturating_add(every);
            }
        }
        let round_start = Instant::now();
        let before = session.now();
        session.round(source);
        ctx.fan.borrow_mut().flush();
        if ctx.cfg.tick_ms > 0 {
            // Spend the wall budget for the minutes just simulated
            // waiting on the request channel, so commands arriving
            // mid-budget are applied — and their acks flushed — before
            // the next round.
            let dt = session.now().saturating_sub(before).max(1);
            let deadline =
                round_start + Duration::from_millis(ctx.cfg.tick_ms.saturating_mul(dt));
            loop {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() || ctx.stopping() {
                    break;
                }
                match rx.recv_timeout(left) {
                    Ok(msg) => {
                        ctx.handle(&mut session, msg);
                        while let Ok(more) = rx.try_recv() {
                            ctx.handle(&mut session, more);
                        }
                        ctx.fan.borrow_mut().flush();
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        }
    }

    let stopped = ctx.stopping();
    if stopped && ctx.cfg.snapshot_dir.is_some() && loop_err.is_none() {
        match ctx.save_snapshot_async(&session, "final") {
            Ok(path) => {
                eprintln!("final snapshot at minute {}: {}", session.now(), path.display());
            }
            Err(e) => loop_err = Some(e),
        }
    }
    {
        let mut fan = ctx.fan.borrow_mut();
        fan.flush();
        fan.flush_owed_final();
    }
    // Wait for every queued snapshot to be durable; a disk-write error
    // from the background thread outranks the generic enqueue failure.
    if let Some(writer) = ctx.snap_writer.take() {
        if let Err(e) = writer.finish() {
            return Err(e);
        }
    }
    if let Some(e) = loop_err {
        return Err(e);
    }
    if let Some(path) = &ctx.cfg.uds {
        std::fs::remove_file(path).ok();
    }
    let result = session.finish(source);
    let fan = ctx.fan.borrow();
    Ok(ServeOutcome {
        result,
        stats: ServeStats {
            connections: ctx.connections,
            requests: ctx.requests,
            events_sent: fan.events_sent,
            events_dropped: fan.events_dropped,
            snapshots: ctx.snapshots,
            snapshot_stall_ms: ctx.snapshot_stall_ms,
        },
        stopped,
    })
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::sched::policy::PolicyKind;
    use crate::sim::SimConfig;
    use crate::util::json::Json;
    use crate::workload::source::WorkloadSource;
    use crate::workload::Workload;
    use std::os::unix::net::UnixStream;

    #[test]
    fn serves_submissions_events_and_shutdown_over_uds() {
        let sock = std::env::temp_dir().join(format!("fitgpp-serve-test-{}.sock", std::process::id()));
        let mut cfg = ServeConfig::new(SimConfig::new(ClusterSpec::tiny(1), PolicyKind::Fifo));
        cfg.sim.paranoid = true;
        cfg.uds = Some(sock.clone());
        cfg.queue_cap = 64;
        let server = thread::spawn(move || {
            let workload = Workload::new(vec![]);
            let mut source = WorkloadSource::new(&workload);
            run(cfg, &mut source).unwrap()
        });
        // Wait for the socket to appear.
        let mut tries = 0;
        let stream = loop {
            match UnixStream::connect(&sock) {
                Ok(s) => break s,
                Err(_) if tries < 200 => {
                    tries += 1;
                    thread::sleep(Duration::from_millis(10));
                }
                Err(e) => panic!("server socket never came up: {e}"),
            }
        };
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(Json::parse(&line).unwrap().get("type").as_str(), Some("hello"));
        writeln!(writer, r#"{{"cmd":"subscribe","seq":1}}"#).unwrap();
        for id in 0..3u32 {
            writeln!(
                writer,
                r#"{{"cmd":"submit","id":{id},"class":"BE","cpu":4,"ram_gb":16,"gpu":0,"exec_time":3,"seq":{}}}"#,
                10 + id
            )
            .unwrap();
        }
        writeln!(writer, r#"{{"cmd":"ping","seq":99}}"#).unwrap();
        let mut finished = 0;
        let mut saw_pong = false;
        while finished < 3 {
            line.clear();
            assert!(reader.read_line(&mut line).unwrap() > 0, "server closed early");
            let v = Json::parse(&line).unwrap();
            match v.get("type").as_str() {
                Some("finished") => finished += 1,
                Some("pong") => saw_pong = true,
                Some("error") => panic!("unexpected error: {line}"),
                _ => {}
            }
        }
        assert!(saw_pong, "ping must be answered");
        writeln!(writer, r#"{{"cmd":"shutdown"}}"#).unwrap();
        let outcome = server.join().unwrap();
        assert!(outcome.stopped);
        assert_eq!(outcome.stats.connections, 1);
        assert_eq!(outcome.result.records.len(), 3);
        assert_eq!(outcome.result.metrics.completed, 3);
        assert!(outcome.stats.events_sent > 0);
        assert_eq!(conservation_line(&outcome.result).split(':').next(), Some("conservation intact"));
    }

    #[test]
    fn slow_subscribers_get_lagged_notices_not_unbounded_buffers() {
        let sock = std::env::temp_dir().join(format!("fitgpp-lag-test-{}.sock", std::process::id()));
        let mut cfg = ServeConfig::new(SimConfig::new(ClusterSpec::tiny(2), PolicyKind::Fifo));
        cfg.uds = Some(sock.clone());
        cfg.queue_cap = 2; // tiny queue: overflow is the point
        let server = thread::spawn(move || {
            let workload = Workload::new(vec![]);
            let mut source = WorkloadSource::new(&workload);
            run(cfg, &mut source).unwrap()
        });
        let mut tries = 0;
        let stream = loop {
            match UnixStream::connect(&sock) {
                Ok(s) => break s,
                Err(_) if tries < 200 => {
                    tries += 1;
                    thread::sleep(Duration::from_millis(10));
                }
                Err(e) => panic!("server socket never came up: {e}"),
            }
        };
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writeln!(writer, r#"{{"cmd":"subscribe"}}"#).unwrap();
        // Submit a burst without reading anything: the 2-line queue must
        // overflow and the overflow must be reported, not buffered.
        for id in 0..40u32 {
            writeln!(
                writer,
                r#"{{"cmd":"submit","id":{id},"class":"BE","cpu":1,"ram_gb":1,"gpu":0,"exec_time":2}}"#
            )
            .unwrap();
        }
        // Give the session time to run the burst while we stay slow.
        thread::sleep(Duration::from_millis(400));
        let mut saw_lagged = false;
        let mut line = String::new();
        while reader.read_line(&mut line).unwrap() > 0 {
            if Json::parse(&line).unwrap().get("type").as_str() == Some("lagged") {
                saw_lagged = true;
                writeln!(writer, r#"{{"cmd":"shutdown"}}"#).unwrap();
            }
            line.clear();
        }
        let outcome = server.join().unwrap();
        assert!(saw_lagged, "overflow must surface as a lagged notice");
        assert!(outcome.stats.events_dropped > 0);
        assert_eq!(outcome.result.metrics.completed, 40);
    }
}
