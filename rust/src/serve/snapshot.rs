//! Versioned, checksummed snapshot envelope and file lifecycle.
//!
//! A snapshot file is the raw [`SimSession`] payload (see
//! [`SimSession::snapshot_bin`]) wrapped in a self-describing envelope,
//! modeled on the live executor's checkpoint format
//! (`runtime/checkpoint.rs`):
//!
//! ```text
//! [magic: u32 LE = "FGSS"] [version: u32 LE] [payload bytes…] [crc: u32 LE]
//! ```
//!
//! The trailing CRC is FNV-1a over everything before it (magic and
//! version included). Decoding is total: truncated, corrupt,
//! wrong-magic, and wrong-version inputs all return a typed
//! [`SnapshotFormatError`] — never a panic, never a hostile allocation.
//! Files are written atomically (temp file + rename) so a crash mid-save
//! can never leave a half-written snapshot where the restore path will
//! find it.

use crate::sched::control::EventSubscriber;
use crate::sim::{SimConfig, SimSession};
use crate::util::bin::{BinReader, BinWriter};
use crate::workload::source::ArrivalSource;
use anyhow::Context;
use std::fmt;
use std::path::{Path, PathBuf};

/// Snapshot file magic: `"FGSS"` (FitGpp Serve Snapshot), little-endian.
/// Distinct from the live checkpoint magic so the two file kinds can
/// never be confused for one another.
pub const MAGIC: u32 = 0x4647_5353;

/// Current snapshot format version. Bumped on any payload layout change;
/// older readers reject newer files with a typed error instead of
/// misparsing them.
pub const VERSION: u32 = 1;

/// Envelope overhead: magic + version header plus the CRC trailer.
const OVERHEAD: usize = 12;

/// Why a snapshot's envelope failed to validate. Every decode failure is
/// one of these (payload-level corruption inside a valid envelope
/// surfaces as [`SimSession::restore_bin`] errors instead).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SnapshotFormatError {
    /// Shorter than the smallest possible envelope.
    TooShort {
        /// The input's actual length in bytes.
        len: usize,
    },
    /// The leading magic is not [`MAGIC`] — not a serve snapshot at all.
    BadMagic {
        /// The four bytes found where the magic belongs.
        found: u32,
    },
    /// A version this build does not read.
    UnsupportedVersion {
        /// The version the file declares.
        found: u32,
    },
    /// The FNV-1a trailer does not match the bytes — truncation or
    /// bit-rot inside the envelope.
    CrcMismatch {
        /// CRC computed over the file's body.
        expected: u32,
        /// CRC the trailer claims.
        found: u32,
    },
}

impl fmt::Display for SnapshotFormatError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotFormatError::TooShort { len } => {
                write!(f, "snapshot too short: {len} bytes, need at least {OVERHEAD}")
            }
            SnapshotFormatError::BadMagic { found } => {
                write!(f, "not a serve snapshot: magic {found:#010x}, expected {MAGIC:#010x}")
            }
            SnapshotFormatError::UnsupportedVersion { found } => {
                write!(f, "unsupported snapshot version {found}, this build reads {VERSION}")
            }
            SnapshotFormatError::CrcMismatch { expected, found } => {
                write!(
                    f,
                    "snapshot checksum mismatch: computed {expected:#010x}, trailer says {found:#010x}"
                )
            }
        }
    }
}

impl std::error::Error for SnapshotFormatError {}

/// FNV-1a over `bytes` — the same checksum `runtime/checkpoint.rs` uses.
fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Serialize a session (which must be at a round boundary) into a
/// complete snapshot file image: header, payload, CRC trailer.
pub fn encode(session: &SimSession) -> Vec<u8> {
    let mut w = BinWriter::new();
    w.u32(MAGIC);
    w.u32(VERSION);
    session.snapshot_bin(&mut w);
    let mut bytes = w.into_bytes();
    let crc = fnv1a(&bytes);
    bytes.extend_from_slice(&crc.to_le_bytes());
    bytes
}

/// Validate the envelope and return the payload slice inside it.
pub fn payload(bytes: &[u8]) -> Result<&[u8], SnapshotFormatError> {
    if bytes.len() < OVERHEAD {
        return Err(SnapshotFormatError::TooShort { len: bytes.len() });
    }
    let (body, tail) = bytes.split_at(bytes.len() - 4);
    let magic = u32::from_le_bytes([body[0], body[1], body[2], body[3]]);
    if magic != MAGIC {
        return Err(SnapshotFormatError::BadMagic { found: magic });
    }
    let version = u32::from_le_bytes([body[4], body[5], body[6], body[7]]);
    if version != VERSION {
        return Err(SnapshotFormatError::UnsupportedVersion { found: version });
    }
    let found = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
    let expected = fnv1a(body);
    if found != expected {
        return Err(SnapshotFormatError::CrcMismatch { expected, found });
    }
    Ok(&body[8..])
}

/// Decode a snapshot image into a restored session: envelope validation,
/// then [`SimSession::restore_bin`] against a configuration equal to the
/// snapshotted one and a fresh instance of the same arrival source.
/// Trailing payload bytes are corruption, not slack.
pub fn decode(
    bytes: &[u8],
    cfg: SimConfig,
    subscribers: Vec<Box<dyn EventSubscriber>>,
    source: &mut dyn ArrivalSource,
) -> anyhow::Result<SimSession> {
    let payload = payload(bytes)?;
    let mut r = BinReader::new(payload);
    let session = SimSession::restore_bin(cfg, &mut r, subscribers, source)?;
    r.expect_end()?;
    Ok(session)
}

/// Write a snapshot image atomically: temp file in the same directory,
/// then rename over the final path.
pub fn save(path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
    let tmp = path.with_extension("snap.tmp");
    std::fs::write(&tmp, bytes)
        .with_context(|| format!("writing snapshot temp file {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming snapshot into place at {}", path.display()))?;
    Ok(())
}

/// Read a snapshot image back.
pub fn load(path: &Path) -> anyhow::Result<Vec<u8>> {
    std::fs::read(path).with_context(|| format!("reading snapshot {}", path.display()))
}

/// Background snapshot writer: the session thread does the fast
/// in-memory [`encode`] and hands `(path, bytes)` over a channel; this
/// thread does the blocking disk work ([`save`]'s tmp + rename), so
/// auto-snapshots never stall the wire. Crash safety is unchanged: a
/// `kill -9` mid-write leaves at worst a `*.snap.tmp` orphan, which
/// [`latest_in`] never selects — the newest *renamed* snapshot is always
/// a complete, checksummed image.
///
/// Writes happen in enqueue order; [`finish`](SnapshotWriter::finish)
/// drains the queue and surfaces the first write error, so a graceful
/// shutdown only returns once every queued snapshot (the final one
/// included) is durable on disk.
pub struct SnapshotWriter {
    tx: Option<std::sync::mpsc::Sender<(PathBuf, Vec<u8>)>>,
    handle: Option<std::thread::JoinHandle<anyhow::Result<u64>>>,
}

impl SnapshotWriter {
    /// Start the writer thread.
    pub fn spawn() -> Self {
        let (tx, rx) = std::sync::mpsc::channel::<(PathBuf, Vec<u8>)>();
        let handle = std::thread::spawn(move || -> anyhow::Result<u64> {
            let mut written = 0u64;
            for (path, bytes) in rx {
                save(&path, &bytes)?;
                written += 1;
            }
            Ok(written)
        });
        SnapshotWriter { tx: Some(tx), handle: Some(handle) }
    }

    /// Queue one snapshot image for writing. Returns `false` when the
    /// writer thread has died — its error surfaces from
    /// [`finish`](SnapshotWriter::finish).
    pub fn enqueue(&self, path: PathBuf, bytes: Vec<u8>) -> bool {
        match &self.tx {
            Some(tx) => tx.send((path, bytes)).is_ok(),
            None => false,
        }
    }

    /// Close the queue, wait for every pending write, and return how many
    /// snapshots were written — or the first write error.
    pub fn finish(mut self) -> anyhow::Result<u64> {
        self.tx.take();
        match self.handle.take() {
            Some(h) => h
                .join()
                .map_err(|_| anyhow::anyhow!("snapshot writer thread panicked"))?,
            None => Ok(0),
        }
    }
}

/// The most recent `*.snap` file in `dir` — by modification time, then
/// name — or `None` when the directory holds no snapshots. The restore
/// path after a hard kill points here.
pub fn latest_in(dir: &Path) -> anyhow::Result<Option<PathBuf>> {
    let mut best: Option<(std::time::SystemTime, PathBuf)> = None;
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("listing snapshot dir {}", dir.display()))?;
    for entry in entries {
        let entry = entry?;
        let path = entry.path();
        if path.extension().and_then(|e| e.to_str()) != Some("snap") {
            continue;
        }
        let mtime = entry
            .metadata()
            .and_then(|m| m.modified())
            .unwrap_or(std::time::UNIX_EPOCH);
        let candidate = (mtime, path);
        if best.as_ref().map(|b| candidate > *b).unwrap_or(true) {
            best = Some(candidate);
        }
    }
    Ok(best.map(|(_, p)| p))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::job::{JobClass, JobSpec};
    use crate::resources::ResourceVec;
    use crate::sched::policy::PolicyKind;
    use crate::workload::source::WorkloadSource;
    use crate::workload::Workload;

    fn specs() -> Vec<JobSpec> {
        (0..24)
            .map(|i| {
                JobSpec::new(
                    i,
                    if i % 3 == 0 { JobClass::Te } else { JobClass::Be },
                    ResourceVec::new(6.0 + (i % 4) as f64 * 8.0, 48.0, (i % 3) as f64),
                    (i as u64) / 2,
                    4 + (i as u64 % 9),
                    (i as u64) % 4,
                )
            })
            .collect()
    }

    fn cfg() -> SimConfig {
        let mut c = SimConfig::new(ClusterSpec::tiny(2), PolicyKind::FitGpp { s: 4.0, p_max: Some(1) });
        c.paranoid = true;
        c
    }

    fn snapshot_at(minute: u64) -> Vec<u8> {
        let workload = Workload::new(specs());
        let mut src = WorkloadSource::new(&workload);
        let mut sess = SimSession::new(cfg(), Vec::new());
        sess.run_until(&mut src, minute);
        encode(&sess)
    }

    #[test]
    fn envelope_round_trips_and_restores() {
        let bytes = snapshot_at(6);
        let workload = Workload::new(specs());
        let mut src = WorkloadSource::new(&workload);
        let mut sess = decode(&bytes, cfg(), Vec::new(), &mut src).unwrap();
        sess.run_to_completion(&mut src);
        let res = sess.finish(&mut src);
        assert_eq!(res.unfinished, 0);
        assert_eq!(res.records.len(), 24);
    }

    #[test]
    fn every_truncation_is_a_typed_error() {
        let bytes = snapshot_at(6);
        for cut in 0..bytes.len() {
            let short = &bytes[..cut];
            let workload = Workload::new(specs());
            let mut src = WorkloadSource::new(&workload);
            assert!(
                decode(short, cfg(), Vec::new(), &mut src).is_err(),
                "cut at {cut} must fail"
            );
        }
    }

    #[test]
    fn bad_magic_and_version_are_rejected() {
        let mut bytes = snapshot_at(3);
        let good = bytes.clone();
        bytes[0] ^= 0xFF;
        assert!(matches!(
            payload(&bytes),
            Err(SnapshotFormatError::BadMagic { .. })
        ));
        bytes = good.clone();
        bytes[4] = 0xEE; // declare a future version
        // Re-seal the CRC so the version check (not the checksum) fires.
        let n = bytes.len();
        let crc = fnv1a(&bytes[..n - 4]);
        bytes[n - 4..].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(
            payload(&bytes),
            Err(SnapshotFormatError::UnsupportedVersion { .. })
        ));
        assert!(payload(&good).is_ok());
    }

    #[test]
    fn every_single_byte_flip_is_caught() {
        let bytes = snapshot_at(4);
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x10;
            let workload = Workload::new(specs());
            let mut src = WorkloadSource::new(&workload);
            assert!(
                decode(&bad, cfg(), Vec::new(), &mut src).is_err(),
                "flip at byte {i} must be caught"
            );
        }
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let mut bytes = snapshot_at(3);
        bytes.push(0);
        let workload = Workload::new(specs());
        let mut src = WorkloadSource::new(&workload);
        assert!(decode(&bytes, cfg(), Vec::new(), &mut src).is_err());
    }

    #[test]
    fn save_load_latest_lifecycle() {
        let dir = std::env::temp_dir().join(format!("fitgpp-snap-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let a = dir.join("auto-000000000005.snap");
        let b = dir.join("auto-000000000009.snap");
        save(&a, &snapshot_at(5)).unwrap();
        save(&b, &snapshot_at(9)).unwrap();
        let latest = latest_in(&dir).unwrap().expect("two snapshots present");
        let bytes = load(&latest).unwrap();
        let workload = Workload::new(specs());
        let mut src = WorkloadSource::new(&workload);
        let mut sess = decode(&bytes, cfg(), Vec::new(), &mut src).unwrap();
        sess.run_to_completion(&mut src);
        assert_eq!(sess.finish(&mut src).unfinished, 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
