//! The traffic frontend: replay an [`ArrivalSource`] against a live
//! service as many concurrent closed-loop wire clients.
//!
//! `attack` is the load half of the `serve`/`attack` CLI pair. It drains
//! a source into a concrete trace up front (feeding synthetic completion
//! ticks to closed-loop generators so they keep producing), partitions
//! the trace round-robin across `clients` connections, and then each
//! client plays its slice as a closed loop over the wire:
//!
//! 1. wait until the spec's submit minute, scaled by
//!    [`AttackConfig::speed_ms_per_minute`] of wall clock per virtual
//!    minute (0 = as fast as the loop allows);
//! 2. send the submit and wait for its ack;
//! 3. with [`AttackConfig::await_finish`], keep reading until the
//!    server's event stream reports that job finished — or the per-wait
//!    timeout fires, which keeps a dropped event (the client was
//!    `lagged`) from deadlocking the run;
//! 4. think for [`AttackConfig::think_ms`], then loop.
//!
//! Every anomaly is counted, not thrown: disconnects, error lines,
//! lagged notices, and finish-wait timeouts all land in the
//! [`AttackReport`], so a load run always reports what actually happened
//! on the wire.

use crate::job::{JobClass, JobSpec};
use crate::serve::wire;
use crate::util::json::Json;
use crate::workload::source::ArrivalSource;
use anyhow::Context;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::thread;
use std::time::{Duration, Instant};

/// How to aim the traffic generator.
#[derive(Debug, Clone)]
pub struct AttackConfig {
    /// TCP address of the server, if attacking over TCP.
    pub tcp: Option<String>,
    /// Unix-domain socket path of the server, if attacking over UDS.
    pub uds: Option<PathBuf>,
    /// Concurrent closed-loop client connections.
    pub clients: usize,
    /// Wall-clock pause between a finish and the client's next submit.
    pub think_ms: u64,
    /// Wall-clock milliseconds per virtual submit minute; 0 fires each
    /// submit as soon as the closed loop allows.
    pub speed_ms_per_minute: u64,
    /// Added to every replayed job id, so an attack can layer on top of
    /// ids the server has already seen.
    pub id_base: u32,
    /// Wait for each job's `finished` event before the next submit.
    pub await_finish: bool,
    /// Per-wait read timeout; a closed loop whose finish event was
    /// dropped by backpressure moves on instead of hanging.
    pub timeout_ms: u64,
}

impl AttackConfig {
    /// Attack defaults: 8 clients, no think time, free-run pacing,
    /// closed-loop with a 60 s finish timeout.
    pub fn new() -> Self {
        AttackConfig {
            tcp: None,
            uds: None,
            clients: 8,
            think_ms: 0,
            speed_ms_per_minute: 0,
            id_base: 0,
            await_finish: true,
            timeout_ms: 60_000,
        }
    }
}

impl Default for AttackConfig {
    fn default() -> Self {
        Self::new()
    }
}

/// What a finished attack saw on the wire, summed over all clients.
#[derive(Debug, Clone, Default)]
pub struct AttackReport {
    /// Client connections that came up.
    pub clients: usize,
    /// Submit requests written.
    pub submitted: u64,
    /// Submit acks read back.
    pub acked: u64,
    /// `finished` events observed for this attack's own job ids.
    pub finished_seen: u64,
    /// `lagged` notices received (events the server dropped for us).
    pub lagged_notices: u64,
    /// `error` lines received.
    pub errors: u64,
    /// Finish-waits that hit the timeout instead of the event.
    pub timeouts: u64,
    /// Clients that lost their connection mid-run.
    pub disconnects: u64,
    /// Wall-clock duration of the whole attack.
    pub wall_ms: u64,
}

impl AttackReport {
    /// One machine-readable JSON line, for scripts and CI logs.
    pub fn to_json_line(&self) -> String {
        format!(
            concat!(
                r#"{{"clients":{},"submitted":{},"acked":{},"finished_seen":{},"#,
                r#""lagged_notices":{},"errors":{},"timeouts":{},"disconnects":{},"wall_ms":{}}}"#
            ),
            self.clients,
            self.submitted,
            self.acked,
            self.finished_seen,
            self.lagged_notices,
            self.errors,
            self.timeouts,
            self.disconnects,
            self.wall_ms
        )
    }

    fn absorb(&mut self, other: &AttackReport) {
        self.submitted += other.submitted;
        self.acked += other.acked;
        self.finished_seen += other.finished_seen;
        self.lagged_notices += other.lagged_notices;
        self.errors += other.errors;
        self.timeouts += other.timeouts;
        self.disconnects += other.disconnects;
    }
}

/// Materialize a source into a replayable trace, up to `limit` jobs.
/// Closed-loop sources stall until they hear completions; each stall is
/// answered by synthetically finishing the oldest not-yet-finished
/// drained job, which linearizes the loop into a trace the wire clients
/// can then close for real against the live server.
pub fn drain_source(source: &mut dyn ArrivalSource, limit: usize) -> Vec<JobSpec> {
    let mut specs: Vec<JobSpec> = Vec::new();
    let mut fed = 0usize;
    while specs.len() < limit {
        match source.next_job() {
            Some(spec) => specs.push(spec),
            None => {
                if source.done() || fed >= specs.len() {
                    break;
                }
                let s = &specs[fed];
                fed += 1;
                let at = s.submit.saturating_add(s.exec_time);
                source.on_job_finished(s.id, at);
            }
        }
    }
    specs
}

/// A connected stream we can split into buffered reader + writer halves,
/// with a read timeout for the finish-wait fallback.
enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Uds(std::os::unix::net::UnixStream),
}

impl Conn {
    fn open(cfg: &AttackConfig) -> anyhow::Result<Conn> {
        if let Some(addr) = &cfg.tcp {
            let s = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
            s.set_nodelay(true).ok();
            s.set_read_timeout(Some(Duration::from_millis(cfg.timeout_ms.max(1))))?;
            return Ok(Conn::Tcp(s));
        }
        #[cfg(unix)]
        if let Some(path) = &cfg.uds {
            let s = std::os::unix::net::UnixStream::connect(path)
                .with_context(|| format!("connecting to {}", path.display()))?;
            s.set_read_timeout(Some(Duration::from_millis(cfg.timeout_ms.max(1))))?;
            return Ok(Conn::Uds(s));
        }
        anyhow::bail!("attack needs --tcp or --uds to aim at")
    }

    fn split(self) -> anyhow::Result<(BufReader<Box<dyn Read + Send>>, Box<dyn Write + Send>)> {
        match self {
            Conn::Tcp(s) => {
                let r = s.try_clone()?;
                Ok((BufReader::new(Box::new(r)), Box::new(s)))
            }
            #[cfg(unix)]
            Conn::Uds(s) => {
                let r = s.try_clone()?;
                Ok((BufReader::new(Box::new(r)), Box::new(s)))
            }
        }
    }
}

/// Append the submit line (newline included) for one replayed spec to
/// `buf`, with its id shifted by `id_base` and its submit minute left to
/// the server's "now" clamp. Takes a caller-owned buffer so client loops
/// reuse one allocation across the whole trace.
fn write_submit_line(buf: &mut String, spec: &JobSpec, id_base: u32, seq: u64) {
    use std::fmt::Write as _;
    let class = match spec.class {
        JobClass::Te => "TE",
        JobClass::Be => "BE",
    };
    buf.clear();
    let _ = write!(
        buf,
        concat!(
            r#"{{"cmd":"submit","id":{},"class":"{}","cpu":{},"ram_gb":{},"gpu":{},"#,
            r#""exec_time":{},"grace_period":{},"tenant":{},"seq":{}}}"#,
            "\n"
        ),
        spec.id.0.wrapping_add(id_base),
        class,
        spec.demand.cpu,
        spec.demand.ram_gb,
        spec.demand.gpu,
        spec.exec_time,
        spec.grace_period,
        spec.tenant.0,
        seq
    );
}

/// One client's closed loop over its slice of the trace.
fn client_loop(cfg: &AttackConfig, slice: &[JobSpec], report: &mut AttackReport) {
    let conn = match Conn::open(cfg) {
        Ok(c) => c,
        Err(_) => {
            report.disconnects += 1;
            return;
        }
    };
    let Ok((mut reader, mut writer)) = conn.split() else {
        report.disconnects += 1;
        return;
    };
    let mut line = String::new();
    if reader.read_line(&mut line).unwrap_or(0) == 0 {
        report.disconnects += 1;
        return;
    }
    if writeln!(writer, r#"{{"cmd":"subscribe","seq":0}}"#).is_err() {
        report.disconnects += 1;
        return;
    }
    let start = Instant::now();
    let mut seq: u64 = 0;
    let mut req = String::with_capacity(160);
    for spec in slice {
        if cfg.speed_ms_per_minute > 0 {
            let due = Duration::from_millis(cfg.speed_ms_per_minute.saturating_mul(spec.submit));
            let elapsed = start.elapsed();
            if due > elapsed {
                thread::sleep(due - elapsed);
            }
        }
        seq += 1;
        write_submit_line(&mut req, spec, cfg.id_base, seq);
        if writer.write_all(req.as_bytes()).is_err() {
            report.disconnects += 1;
            return;
        }
        report.submitted += 1;
        let my_id = u64::from(spec.id.0.wrapping_add(cfg.id_base));
        let mut acked = false;
        let mut finished = !cfg.await_finish;
        let wait_start = Instant::now();
        while !(acked && finished) {
            if wait_start.elapsed() >= Duration::from_millis(cfg.timeout_ms) {
                report.timeouts += 1;
                break;
            }
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) => {
                    report.disconnects += 1;
                    return;
                }
                Ok(_) => {}
                // A read timeout surfaces as WouldBlock or TimedOut
                // depending on the platform; both mean "keep waiting
                // until the outer deadline says stop".
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue;
                }
                Err(_) => {
                    report.disconnects += 1;
                    return;
                }
            }
            let Ok(v) = Json::parse(&line) else { continue };
            match v.get("type").as_str() {
                Some("ack") if v.get("seq").as_u64() == Some(seq) => {
                    acked = true;
                    report.acked += 1;
                }
                Some("finished") if v.get("job").as_u64() == Some(my_id) => {
                    finished = true;
                    report.finished_seen += 1;
                }
                Some("lagged") => report.lagged_notices += 1,
                Some("error") => report.errors += 1,
                _ => {}
            }
        }
        if cfg.think_ms > 0 {
            thread::sleep(Duration::from_millis(cfg.think_ms));
        }
    }
}

/// Run the whole attack: partition `specs` round-robin across
/// [`AttackConfig::clients`] threads, play every slice as a closed loop,
/// and sum what came back.
pub fn run(cfg: &AttackConfig, specs: Vec<JobSpec>) -> anyhow::Result<AttackReport> {
    anyhow::ensure!(cfg.clients > 0, "attack needs at least one client");
    let started = Instant::now();
    let n = cfg.clients.min(specs.len()).max(1);
    let mut slices: Vec<Vec<JobSpec>> = (0..n).map(|_| Vec::new()).collect();
    for (i, spec) in specs.into_iter().enumerate() {
        slices[i % n].push(spec);
    }
    let handles: Vec<_> = slices
        .into_iter()
        .map(|slice| {
            let cfg = cfg.clone();
            thread::spawn(move || {
                let mut report = AttackReport::default();
                client_loop(&cfg, &slice, &mut report);
                report
            })
        })
        .collect();
    let mut total = AttackReport { clients: n, ..AttackReport::default() };
    for h in handles {
        match h.join() {
            Ok(r) => total.absorb(&r),
            Err(_) => total.disconnects += 1,
        }
    }
    total.wall_ms = started.elapsed().as_millis() as u64;
    Ok(total)
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::resources::ResourceVec;
    use crate::sched::policy::PolicyKind;
    use crate::serve::server::{self, ServeConfig};
    use crate::sim::SimConfig;
    use crate::workload::source::WorkloadSource;
    use crate::workload::Workload;

    #[test]
    fn drain_linearizes_a_plain_workload() {
        let specs: Vec<JobSpec> = (0..10)
            .map(|i| {
                JobSpec::new(i, JobClass::Be, ResourceVec::new(1.0, 1.0, 0.0), i as u64, 5, 0)
            })
            .collect();
        let workload = Workload::new(specs);
        let mut src = WorkloadSource::new(&workload);
        let drained = drain_source(&mut src, 1000);
        assert_eq!(drained.len(), 10);
        let capped = {
            let mut src = WorkloadSource::new(&workload);
            drain_source(&mut src, 4)
        };
        assert_eq!(capped.len(), 4);
    }

    #[test]
    fn closed_loop_attack_against_a_live_server() {
        let sock =
            std::env::temp_dir().join(format!("fitgpp-attack-test-{}.sock", std::process::id()));
        let mut scfg = ServeConfig::new(SimConfig::new(ClusterSpec::tiny(2), PolicyKind::Fifo));
        scfg.sim.paranoid = true;
        scfg.uds = Some(sock.clone());
        let server_sock = sock.clone();
        let server = std::thread::spawn(move || {
            let workload = Workload::new(vec![]);
            let mut source = WorkloadSource::new(&workload);
            let mut cfg = scfg;
            cfg.uds = Some(server_sock);
            server::run(cfg, &mut source).unwrap()
        });
        let mut tries = 0;
        loop {
            match std::os::unix::net::UnixStream::connect(&sock) {
                Ok(_) => break,
                Err(_) if tries < 200 => {
                    tries += 1;
                    thread::sleep(Duration::from_millis(10));
                }
                Err(e) => panic!("server socket never came up: {e}"),
            }
        }
        let specs: Vec<JobSpec> = (0..12)
            .map(|i| {
                JobSpec::new(i, JobClass::Be, ResourceVec::new(2.0, 4.0, 0.0), 0, 2, 0)
            })
            .collect();
        let mut acfg = AttackConfig::new();
        acfg.uds = Some(sock.clone());
        acfg.clients = 4;
        acfg.timeout_ms = 30_000;
        let report = run(&acfg, specs).unwrap();
        assert_eq!(report.submitted, 12);
        assert_eq!(report.acked, 12);
        assert_eq!(report.finished_seen, 12);
        assert_eq!(report.disconnects, 0);
        // Tell the server we're done.
        let mut s = std::os::unix::net::UnixStream::connect(&sock).unwrap();
        writeln!(s, r#"{{"cmd":"shutdown"}}"#).unwrap();
        let outcome = server.join().unwrap();
        assert_eq!(outcome.result.metrics.completed, 12);
        assert!(outcome.stats.connections >= 5);
    }
}
