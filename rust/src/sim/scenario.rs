//! Deterministic scenario scripts: timed control-plane command injections
//! plus standing rules, replayed identically by both simulator engines.
//!
//! A [`ScenarioScript`] is data — a list of `(minute, command)` pairs and
//! an optional *TE patience* rule — parsed from a small JSON file
//! (`fitgpp simulate --scenario <file>`) or built in code. The
//! [`ScenarioDriver`] executes it against a
//! [`ClusterController`](crate::sched::control::ClusterController)-driven
//! run:
//!
//! * **Timed commands** fire at their minute, before that minute's
//!   scheduling round (so a cancellation beats a same-minute completion,
//!   and a node failure is visible to the round's admission pass).
//! * **TE patience** models the paper's impatient trial-and-error user:
//!   any TE job still waiting `patience` minutes after submission is
//!   killed ([`SchedulerCommand::Cancel`]) — exactly the "user watches the
//!   queue and gives up" behaviour §2 motivates preemption with.
//! * **Deferred cancellations**: a `cancel` whose target has not arrived
//!   yet is held until the job exists scheduler-side — it then applies the
//!   minute after the target's submission — or dropped if the target
//!   already retired. This makes scenario outcomes independent of
//!   `arrival_lookahead` — a cancel can never hit a job merely because a
//!   wide pull window staged it early — and costs no extra wakeups: an
//!   unarrived target's own arrival already pins the event horizon.
//!
//! Every future action minute is mirrored into the
//! [`EventClock`](crate::sched::EventClock)'s control heap, so the
//! event-horizon engine never fast-forwards across an injection point —
//! scenario runs stay byte-identical across engines and lookahead
//! settings (pinned by the JSONL golden test).
//!
//! ## File format
//!
//! ```json
//! {
//!   "te_patience": 30,
//!   "commands": [
//!     {"at": 60,  "cmd": "node_down", "node": 3},
//!     {"at": 240, "cmd": "node_up",   "node": 3},
//!     {"at": 120, "cmd": "drain",     "node": 2},
//!     {"at": 360, "cmd": "cancel",    "job": 17},
//!     {"at": 90,  "cmd": "reclassify", "job": 5, "class": "TE"},
//!     {"at": 45,  "cmd": "resize",    "node": 1, "cpu": 16, "ram_gb": 128, "gpu": 4},
//!     {"at": 180, "cmd": "set_quota",  "tenant": 2, "size": 0.25},
//!     {"at": 200, "cmd": "set_weight", "tenant": 2, "weight": 4}
//!   ]
//! }
//! ```
//!
//! `set_quota` caps the tenant's occupied Size (Eq. 1, against the
//! cluster's total capacity; `0` is a full stop) and `set_weight` sets its
//! weighted-fair share — the timed "quota squeeze" knobs of the tenant
//! scenario family (see EXPERIMENTS.md).
//!
//! `submit` is deliberately not a scenario command: arrivals belong to the
//! [`ArrivalSource`](crate::workload::source::ArrivalSource) (job ids must
//! stay dense in yield order); [`SchedulerCommand::Submit`] exists for
//! live/manual driving of the controller.

use crate::job::{JobClass, JobId};
use crate::job_table::JobTable;
use crate::resources::ResourceVec;
use crate::sched::clock::EventClock;
use crate::sched::control::SchedulerCommand;
use crate::sched::Scheduler;
use crate::util::bin::{BinReader, BinWriter};
use crate::util::json::Json;
use crate::Minutes;
use anyhow::{bail, Context, Result};
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::path::Path;

/// A deterministic scenario: timed commands plus the TE-patience rule.
/// Plain data — clones into [`SimConfig`](crate::sim::SimConfig), compares
/// in tests, and parses from JSON.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ScenarioScript {
    /// `(minute, command)` injections; stable-sorted by minute when the
    /// driver is built, so same-minute commands apply in listed order.
    pub commands: Vec<(Minutes, SchedulerCommand)>,
    /// Kill any TE job still waiting this many minutes after submission
    /// (≥ 1; the paper's impatient interactive user).
    pub te_patience: Option<Minutes>,
}

impl ScenarioScript {
    /// An empty scenario (attaching it changes nothing — pinned by the
    /// equivalence tests).
    pub fn new() -> Self {
        ScenarioScript::default()
    }

    /// Builder: add a timed command.
    pub fn at(mut self, minute: Minutes, cmd: SchedulerCommand) -> Self {
        self.commands.push((minute, cmd));
        self
    }

    /// Builder: set the TE patience threshold (minutes, ≥ 1).
    pub fn with_te_patience(mut self, patience: Minutes) -> Self {
        assert!(patience >= 1, "patience must be at least one minute");
        self.te_patience = Some(patience);
        self
    }

    /// Number of timed commands.
    pub fn len(&self) -> usize {
        self.commands.len()
    }

    /// True when the script has no timed commands and no standing rule.
    pub fn is_empty(&self) -> bool {
        self.commands.is_empty() && self.te_patience.is_none()
    }

    /// Parse the JSON scenario format (see the module docs).
    pub fn parse(text: &str) -> Result<ScenarioScript> {
        let v = Json::parse(text).map_err(|e| anyhow::anyhow!("scenario json: {e}"))?;
        let mut script = ScenarioScript::new();
        match v.get("te_patience") {
            Json::Null => {}
            p => {
                let p = p.as_u64().context("te_patience must be a non-negative integer")?;
                if p == 0 {
                    bail!("te_patience must be at least one minute");
                }
                script.te_patience = Some(p);
            }
        }
        let empty: Vec<Json> = Vec::new();
        let items: &[Json] = match v.get("commands") {
            Json::Null => &empty, // key absent: patience-only scenarios are fine
            arr => arr
                .as_arr()
                .context("'commands' must be an array of command objects")?,
        };
        for (i, item) in items.iter().enumerate() {
            let at = item
                .get("at")
                .as_u64()
                .with_context(|| format!("command {i}: missing integer 'at'"))?;
            let kind = item
                .get("cmd")
                .as_str()
                .with_context(|| format!("command {i}: missing 'cmd'"))?;
            // Range-checked u32 ids: a typo'd out-of-range id must be a
            // parse error, never a silent truncation onto some other
            // job/node.
            let id32 = |key: &str| -> Result<u32> {
                let v = item.get(key).as_u64().with_context(|| {
                    format!("command {i} ({kind}): missing integer '{key}'")
                })?;
                u32::try_from(v).map_err(|_| {
                    anyhow::anyhow!("command {i} ({kind}): '{key}' {v} exceeds u32 range")
                })
            };
            let job = |key: &str| -> Result<JobId> { Ok(JobId(id32(key)?)) };
            let node = || -> Result<crate::cluster::NodeId> {
                Ok(crate::cluster::NodeId(id32("node")?))
            };
            let cmd = match kind {
                "cancel" => SchedulerCommand::Cancel { job: job("job")? },
                "reclassify" => {
                    let class = match item.get("class").as_str() {
                        Some("TE") | Some("te") => JobClass::Te,
                        Some("BE") | Some("be") => JobClass::Be,
                        _ => bail!("command {i} (reclassify): 'class' must be \"TE\" or \"BE\""),
                    };
                    SchedulerCommand::Reclassify { job: job("job")?, class }
                }
                "node_down" => SchedulerCommand::NodeDown { node: node()? },
                "node_up" => SchedulerCommand::NodeUp { node: node()? },
                "drain" => SchedulerCommand::Drain { node: node()? },
                "set_quota" => {
                    let size = item.get("size").as_f64().with_context(|| {
                        format!("command {i} (set_quota): missing number 'size'")
                    })?;
                    if !size.is_finite() || size < 0.0 {
                        bail!("command {i} (set_quota): 'size' must be finite and non-negative");
                    }
                    SchedulerCommand::SetQuota {
                        tenant: crate::job::TenantId(id32("tenant")?),
                        size,
                    }
                }
                "set_weight" => {
                    let weight = item.get("weight").as_u64().with_context(|| {
                        format!("command {i} (set_weight): missing integer 'weight'")
                    })?;
                    let weight = u32::try_from(weight).map_err(|_| {
                        anyhow::anyhow!("command {i} (set_weight): 'weight' exceeds u32 range")
                    })?;
                    if weight == 0 {
                        bail!("command {i} (set_weight): 'weight' must be at least 1");
                    }
                    SchedulerCommand::SetWeight {
                        tenant: crate::job::TenantId(id32("tenant")?),
                        weight,
                    }
                }
                "resize" => {
                    let axis = |key: &str| -> Result<f64> {
                        item.get(key).as_f64().with_context(|| {
                            format!("command {i} (resize): missing number '{key}'")
                        })
                    };
                    SchedulerCommand::Resize {
                        node: node()?,
                        capacity: ResourceVec::new(axis("cpu")?, axis("ram_gb")?, axis("gpu")?),
                    }
                }
                "submit" => bail!(
                    "command {i}: 'submit' is not a scenario command — arrivals \
                     belong to the workload source (job ids must stay dense)"
                ),
                other => bail!("command {i}: unknown command {other:?}"),
            };
            script.commands.push((at, cmd));
        }
        Ok(script)
    }

    /// Read and parse a scenario file.
    pub fn from_file(path: &Path) -> Result<ScenarioScript> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario file {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing scenario file {}", path.display()))
    }
}

/// Executes a [`ScenarioScript`] against a run: tracks which timed
/// commands have fired, which TE jobs are on patience watch, and which
/// cancellations are deferred until their target arrives. One driver per
/// run; state is deterministic given the event sequence.
pub struct ScenarioDriver {
    timed: Vec<(Minutes, SchedulerCommand)>,
    cursor: usize,
    te_patience: Option<Minutes>,
    /// `(deadline minute, TE job)` patience watches.
    deadlines: BinaryHeap<Reverse<(Minutes, u32)>>,
    /// Cancellations whose target has not arrived yet; retried each
    /// minute.
    holdover: Vec<JobId>,
}

impl ScenarioDriver {
    /// Build a driver from a script (stable-sorts the timed commands).
    pub fn new(script: ScenarioScript) -> Self {
        let mut timed = script.commands;
        timed.sort_by_key(|(at, _)| *at);
        ScenarioDriver {
            timed,
            cursor: 0,
            te_patience: script.te_patience,
            deadlines: BinaryHeap::new(),
            holdover: Vec::new(),
        }
    }

    /// Mirror every timed command minute into the clock's control heap so
    /// the event-horizon engine cannot fast-forward across one. Call once
    /// before the run's first round.
    pub fn prime(&self, clock: &mut EventClock) {
        for (at, _) in &self.timed {
            clock.push_control(*at);
        }
    }

    /// Commands to apply at `now`, plus new wakeup minutes the caller must
    /// push into the clock (deferred-cancel retries). Call once per
    /// scheduling round, before [`ClusterController::step`]
    /// (crate::sched::control::ClusterController::step).
    pub fn due(
        &mut self,
        now: Minutes,
        sched: &Scheduler,
        jobs: &JobTable,
    ) -> (Vec<SchedulerCommand>, Vec<Minutes>) {
        let mut cmds = Vec::new();
        let mut wake = Vec::new();

        // Held-over cancellations first — they were due at an earlier
        // minute.
        if !self.holdover.is_empty() {
            let pending = std::mem::take(&mut self.holdover);
            for id in pending {
                self.route_cancel(id, now, sched, jobs, &mut cmds, &mut wake);
            }
        }

        // Timed commands due this minute, in script order.
        while self.cursor < self.timed.len() && self.timed[self.cursor].0 <= now {
            let cmd = self.timed[self.cursor].1.clone();
            self.cursor += 1;
            match cmd {
                SchedulerCommand::Cancel { job } => {
                    self.route_cancel(job, now, sched, jobs, &mut cmds, &mut wake);
                }
                other => cmds.push(other),
            }
        }

        // Patience deadlines due this minute: kill TE jobs that never got
        // scheduled in time. Stale watches are dropped silently: the job
        // started, retired, or was reclassified to BE (a user who demotes
        // a trial to batch is explicitly choosing to wait). A BE job
        // promoted to TE mid-queue gains no watch — patience measures
        // time since a TE *submission*, the only moment the user's
        // interactive clock starts.
        while let Some(Reverse((at, id))) = self.deadlines.peek().copied() {
            if at > now {
                break;
            }
            self.deadlines.pop();
            let id = JobId(id);
            let still_waiting_te = jobs
                .get(id)
                .is_some_and(|j| j.is_te() && j.first_start.is_none());
            if still_waiting_te && sched.tracks(id) {
                cmds.push(SchedulerCommand::Cancel { job: id });
            }
        }

        (cmds, wake)
    }

    /// Put this round's processed arrivals on patience watch (TE jobs that
    /// did not start in their arrival round). Returns deadline minutes the
    /// caller must push into the clock. Call after each round.
    pub fn watch_arrivals(
        &mut self,
        now: Minutes,
        arrivals: &[JobId],
        jobs: &JobTable,
    ) -> Vec<Minutes> {
        let Some(patience) = self.te_patience else {
            return Vec::new();
        };
        let mut wake = Vec::new();
        for id in arrivals {
            let waiting_te = jobs
                .get(*id)
                .is_some_and(|j| j.is_te() && j.first_start.is_none());
            if waiting_te {
                let deadline = now.saturating_add(patience);
                self.deadlines.push(Reverse((deadline, id.0)));
                wake.push(deadline);
            }
        }
        wake
    }

    /// Serialize the driver's run state for a snapshot. The timed command
    /// list is config (rebuilt from the same script on restore); only the
    /// cursor, the pending patience watches, and the held-over
    /// cancellations are state.
    pub fn snapshot_bin(&self, w: &mut BinWriter) {
        w.usize(self.cursor);
        // Sorted for deterministic bytes; the heap's total order means the
        // multiset determines pop order.
        let mut watches: Vec<(Minutes, u32)> =
            self.deadlines.iter().map(|Reverse(e)| *e).collect();
        watches.sort_unstable();
        w.seq(watches.len());
        for (at, id) in watches {
            w.u64(at);
            w.u32(id);
        }
        w.seq(self.holdover.len());
        for id in &self.holdover {
            w.u32(id.0);
        }
    }

    /// Restore state written by [`ScenarioDriver::snapshot_bin`] into a
    /// driver freshly built from the same script.
    pub fn restore_bin(&mut self, r: &mut BinReader) -> Result<()> {
        let cursor = r.usize()?;
        if cursor > self.timed.len() {
            bail!(
                "snapshot corrupt: scenario cursor {cursor} exceeds {} timed commands",
                self.timed.len()
            );
        }
        self.cursor = cursor;
        self.deadlines.clear();
        for _ in 0..r.seq()? {
            let at = r.u64()?;
            let id = r.u32()?;
            self.deadlines.push(Reverse((at, id)));
        }
        self.holdover.clear();
        for _ in 0..r.seq()? {
            self.holdover.push(JobId(r.u32()?));
        }
        Ok(())
    }

    /// Apply, drop, or defer one cancellation:
    /// * target tracked by the scheduler → apply now;
    /// * target already retired (finished or cancelled) → stale, drop;
    /// * target staged but not arrived → hold, wake the minute after its
    ///   (known) submission — it is tracked from then on;
    /// * target not yielded by the source at all yet → hold with **no**
    ///   wakeup: its arrival already pins the event-horizon burn target,
    ///   and the re-check at that minute lands in the staged case above.
    ///   A holdover for an id the source never yields therefore costs
    ///   nothing (no per-minute wakeups) and is dropped at run end.
    ///
    /// Deterministic across `arrival_lookahead` by construction: residency
    /// without arrival never makes a job cancellable, and both deferral
    /// paths converge on the same cancel minute (submission + 1).
    fn route_cancel(
        &mut self,
        id: JobId,
        now: Minutes,
        sched: &Scheduler,
        jobs: &JobTable,
        cmds: &mut Vec<SchedulerCommand>,
        wake: &mut Vec<Minutes>,
    ) {
        if sched.tracks(id) {
            cmds.push(SchedulerCommand::Cancel { job: id });
        } else if let Some(job) = jobs.get(id) {
            // Staged inside the lookahead window, not arrived yet.
            self.holdover.push(id);
            wake.push(job.spec.submit.saturating_add(1).max(now.saturating_add(1)));
        } else if jobs.seen(id) {
            // Already retired — the cancel lost the race; nothing to do.
        } else {
            self.holdover.push(id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeId;

    #[test]
    fn parse_full_scenario() {
        let text = r#"{
            "te_patience": 30,
            "commands": [
                {"at": 60, "cmd": "node_down", "node": 3},
                {"at": 240, "cmd": "node_up", "node": 3},
                {"at": 120, "cmd": "drain", "node": 2},
                {"at": 360, "cmd": "cancel", "job": 17},
                {"at": 90, "cmd": "reclassify", "job": 5, "class": "TE"},
                {"at": 45, "cmd": "resize", "node": 1, "cpu": 16, "ram_gb": 128, "gpu": 4}
            ]
        }"#;
        let s = ScenarioScript::parse(text).unwrap();
        assert_eq!(s.te_patience, Some(30));
        assert_eq!(s.len(), 6);
        assert!(s
            .commands
            .contains(&(60, SchedulerCommand::NodeDown { node: NodeId(3) })));
        assert!(s.commands.contains(&(
            90,
            SchedulerCommand::Reclassify { job: JobId(5), class: JobClass::Te }
        )));
        assert!(s.commands.contains(&(
            45,
            SchedulerCommand::Resize {
                node: NodeId(1),
                capacity: ResourceVec::new(16.0, 128.0, 4.0)
            }
        )));
    }

    #[test]
    fn parse_rejects_bad_scenarios() {
        for bad in [
            "not json",
            r#"{"te_patience": 0}"#,
            r#"{"commands": [{"cmd": "cancel", "job": 1}]}"#,
            r#"{"commands": [{"at": 5, "cmd": "warp"}]}"#,
            r#"{"commands": [{"at": 5, "cmd": "submit"}]}"#,
            r#"{"commands": [{"at": 5, "cmd": "reclassify", "job": 1, "class": "XX"}]}"#,
            r#"{"commands": [{"at": 5, "cmd": "resize", "node": 0, "cpu": 1}]}"#,
            r#"{"commands": [{"at": 5, "cmd": "cancel", "job": 4294967296}]}"#,
            r#"{"commands": {"at": 5, "cmd": "drain", "node": 0}}"#,
            r#"{"commands": [{"at": 5, "cmd": "set_quota", "tenant": 0}]}"#,
            r#"{"commands": [{"at": 5, "cmd": "set_quota", "tenant": 0, "size": -1}]}"#,
            r#"{"commands": [{"at": 5, "cmd": "set_weight", "tenant": 0, "weight": 0}]}"#,
            r#"{"commands": [{"at": 5, "cmd": "set_weight", "weight": 2}]}"#,
        ] {
            assert!(ScenarioScript::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn parse_tenant_commands() {
        use crate::job::TenantId;
        let s = ScenarioScript::parse(
            r#"{"commands": [
                {"at": 180, "cmd": "set_quota", "tenant": 2, "size": 0.25},
                {"at": 200, "cmd": "set_weight", "tenant": 2, "weight": 4}
            ]}"#,
        )
        .unwrap();
        assert!(s.commands.contains(&(
            180,
            SchedulerCommand::SetQuota { tenant: TenantId(2), size: 0.25 }
        )));
        assert!(s.commands.contains(&(
            200,
            SchedulerCommand::SetWeight { tenant: TenantId(2), weight: 4 }
        )));
    }

    #[test]
    fn empty_scenario_is_empty() {
        assert!(ScenarioScript::parse("{}").unwrap().is_empty());
        assert!(!ScenarioScript::new().with_te_patience(5).is_empty());
    }

    #[test]
    fn driver_sorts_and_fires_in_minute_order() {
        let script = ScenarioScript::new()
            .at(9, SchedulerCommand::NodeUp { node: NodeId(0) })
            .at(3, SchedulerCommand::Drain { node: NodeId(0) });
        let mut driver = ScenarioDriver::new(script);
        let mut clock = EventClock::new();
        driver.prime(&mut clock);
        assert_eq!(clock.next_control_at(), Some(3));

        let sched = Scheduler::new(
            &crate::cluster::ClusterSpec::tiny(1),
            crate::sched::SchedConfig::new(crate::sched::policy::PolicyKind::Fifo),
        );
        let jobs = JobTable::new();
        let (cmds, _) = driver.due(2, &sched, &jobs);
        assert!(cmds.is_empty());
        let (cmds, _) = driver.due(3, &sched, &jobs);
        assert_eq!(cmds, vec![SchedulerCommand::Drain { node: NodeId(0) }]);
        let (cmds, _) = driver.due(10, &sched, &jobs);
        let late = vec![SchedulerCommand::NodeUp { node: NodeId(0) }];
        assert_eq!(cmds, late, "late fire catches up");
    }

    #[test]
    fn cancel_for_unseen_job_is_held_without_wakeups() {
        let script = ScenarioScript::new().at(0, SchedulerCommand::Cancel { job: JobId(0) });
        let mut driver = ScenarioDriver::new(script);
        let sched = Scheduler::new(
            &crate::cluster::ClusterSpec::tiny(1),
            crate::sched::SchedConfig::new(crate::sched::policy::PolicyKind::Fifo),
        );
        let mut jobs = JobTable::new();
        let (cmds, wake) = driver.due(0, &sched, &jobs);
        assert!(cmds.is_empty(), "target does not exist yet");
        assert!(
            wake.is_empty(),
            "an unseen target must not force per-minute wakeups — its arrival pins the horizon"
        );

        // Once the job is staged (pulled, not arrived), the retry is
        // scheduled for the minute after its known submission.
        jobs.insert(crate::job::Job::new(crate::job::JobSpec::new(
            0,
            JobClass::Be,
            ResourceVec::new(1.0, 1.0, 0.0),
            7,
            5,
            0,
        )));
        let (cmds, wake) = driver.due(1, &sched, &jobs);
        assert!(cmds.is_empty());
        assert_eq!(wake, vec![8], "wake the minute after submit=7");
    }
}
