//! Sharded simulation cells: partition one big cluster into independent
//! slices and replay each slice on its own core.
//!
//! A single [`Simulator`] run is inherently serial — every simulated
//! minute mutates one scheduler. For *scale* replays (the 1M-job scale
//! bench) the bottleneck is that serial hot path, so this module trades
//! global scheduling fidelity for wall-clock speed the same way large
//! real clusters do: statically partition the nodes into `K` contiguous
//! **cells**, route each job to a cell by `id % K`, and run every cell as
//! a completely independent simulation. Cells never exchange jobs, so
//! there is no cross-cell contention and the cells parallelize perfectly
//! over [`parallel_map`]'s work-stealing workers (an idle worker steals
//! the next unclaimed cell, so a slow cell never gates the rest).
//!
//! The partition is **deterministic**: the node slices, the job routing,
//! and every per-cell seed depend only on `(spec, K)`, so a sharded run
//! is reproducible and — the pin this module's tests enforce —
//! byte-identical whether its cells execute serially or on a thread pool.
//! With `K = 1` the sharded driver degenerates to the plain, untouched
//! [`Simulator::run`] path (same single cell, same seed, same result).
//!
//! Sharding is an *approximation knob*, not an equivalence-preserving
//! refactor: a `K`-cell run answers "how fast can we chew through this
//! trace", not "what would the one-cluster scheduler have done". Results
//! therefore merge conservatively — records concatenate (and re-sort into
//! job-id order), counters sum, makespan is the max over cells — and the
//! scale bench reports cells explicitly so numbers are never silently
//! cross-compared between different `K`.

use crate::cluster::ClusterSpec;
use crate::metrics::StreamingMetrics;
use crate::sched::SchedStats;
use crate::sim::{SimConfig, SimResult, Simulator};
use crate::sweep::parallel_map;
use crate::workload::Workload;

/// Split `spec`'s nodes into `k` contiguous, non-overlapping slices whose
/// concatenation is the original node list. Sizes differ by at most one
/// (the first `nodes % k` cells get the extra node); `k` is clamped to
/// `[1, nodes]` so no cell is ever empty.
pub fn split_cluster(spec: &ClusterSpec, k: usize) -> Vec<ClusterSpec> {
    let n = spec.nodes.len();
    let k = k.clamp(1, n.max(1));
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    for cell in 0..k {
        let len = base + usize::from(cell < extra);
        out.push(ClusterSpec {
            nodes: spec.nodes[start..start + len].to_vec(),
        });
        start += len;
    }
    debug_assert_eq!(start, n, "slices must cover every node exactly once");
    out
}

/// Route `workload`'s jobs into `k` per-cell workloads by `id % k` —
/// deterministic, order-preserving within a cell, and independent of the
/// thread count. Job ids are kept verbatim (the job table handles sparse
/// ids), so merged records sort back into the global submission order.
pub fn split_workload(workload: &Workload, k: usize) -> Vec<Workload> {
    let k = k.max(1);
    let mut out: Vec<Workload> = (0..k).map(|_| Workload { jobs: Vec::new() }).collect();
    for spec in &workload.jobs {
        out[(spec.id.0 as usize) % k].jobs.push(spec.clone());
    }
    out
}

/// Field-wise sum of two cells' scheduler counters.
fn add_stats(acc: &mut SchedStats, s: &SchedStats) {
    acc.preemption_signals += s.preemption_signals;
    acc.fallback_plans += s.fallback_plans;
    acc.plans += s.plans;
    acc.placements += s.placements;
    acc.completions += s.completions;
    acc.te_no_preemption += s.te_no_preemption;
    acc.ticks += s.ticks;
    acc.replans += s.replans;
    acc.fast_forwards += s.fast_forwards;
    acc.fast_forwarded_ticks += s.fast_forwarded_ticks;
    acc.internal_errors += s.internal_errors;
    acc.admission_skips += s.admission_skips;
}

/// Merge per-cell results into one [`SimResult`]: records concatenate and
/// re-sort into job-id order, metrics sinks merge (they are mergeable by
/// design — the sweep pools them the same way), counters and `unfinished`
/// sum, and `makespan` is the slowest cell's. `peak_live` sums the
/// per-cell high-water marks — an upper bound on the simultaneous global
/// resident set. Panics on an empty part list.
pub fn merge_results(parts: Vec<SimResult>) -> SimResult {
    assert!(!parts.is_empty(), "merge_results needs at least one cell");
    let policy = parts[0].policy;
    let record_jobs = parts[0].record_jobs;
    let mut records = Vec::new();
    let mut metrics = StreamingMetrics::new();
    let mut sched_stats = SchedStats::default();
    let mut makespan = 0;
    let mut unfinished = 0usize;
    let mut peak_live = 0usize;
    let mut prediction_updates = 0u64;
    for part in parts {
        records.extend(part.records);
        metrics.merge(&part.metrics);
        add_stats(&mut sched_stats, &part.sched_stats);
        makespan = makespan.max(part.makespan);
        unfinished += part.unfinished;
        peak_live += part.peak_live;
        prediction_updates += part.prediction_updates;
    }
    records.sort_by_key(|r| r.id);
    SimResult {
        policy,
        records,
        metrics,
        sched_stats,
        makespan,
        unfinished,
        peak_live,
        record_jobs,
        prediction_updates,
    }
}

/// Driver for a sharded run: a base [`SimConfig`] template applied to
/// every cell, a cell count, and a worker-thread knob.
pub struct ShardedSim {
    cfg: SimConfig,
    cells: usize,
    threads: usize,
}

impl ShardedSim {
    /// Shard `cfg`'s cluster into `cells` slices (clamped to the node
    /// count; `0` is treated as `1`). Worker threads default to one per
    /// cell, capped by `FITGPP_THREADS` / available parallelism — see
    /// [`ShardedSim::with_threads`].
    pub fn new(cfg: SimConfig, cells: usize) -> Self {
        assert!(
            cfg.scenario.is_none(),
            "scenario scripts address global job/node ids and are not supported in sharded runs"
        );
        let cells = cells.clamp(1, cfg.cluster.nodes.len().max(1));
        ShardedSim { cfg, cells, threads: 0 }
    }

    /// Pin the worker-thread count (`1` = serial reference order, the
    /// byte-equivalence oracle; `0` = resolve from `FITGPP_THREADS`, else
    /// all cores).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// The effective cell count after clamping.
    pub fn cells(&self) -> usize {
        self.cells
    }

    /// Per-cell simulator configs: the base template with the cell's node
    /// slice and a decorrelated policy-RNG seed (`seed + cell`; cell 0
    /// keeps the base seed, so a 1-cell shard is the plain run).
    pub fn cell_configs(&self) -> Vec<SimConfig> {
        split_cluster(&self.cfg.cluster, self.cells)
            .into_iter()
            .enumerate()
            .map(|(i, cluster)| {
                let mut cfg = self.cfg.clone();
                cfg.cluster = cluster;
                cfg.seed = cfg.seed.wrapping_add(i as u64);
                cfg
            })
            .collect()
    }

    /// Run `workload` across the cells and merge the results. With one
    /// cell this is exactly [`Simulator::run`] on the unmodified config —
    /// the default path stays untouched. With `K > 1`, cells run on
    /// [`parallel_map`]'s work-stealing workers; the merged result is
    /// independent of the thread count.
    pub fn run(&self, workload: &Workload) -> SimResult {
        if self.cells == 1 {
            return Simulator::new(self.cfg.clone()).run(workload);
        }
        let shards = split_workload(workload, self.cells);
        let jobs: Vec<(SimConfig, Workload)> = self
            .cell_configs()
            .into_iter()
            .zip(shards)
            .collect();
        let threads = if self.threads > 0 {
            self.threads
        } else {
            resolve_threads(self.cells)
        };
        let parts = parallel_map(&jobs, threads, |_, (cfg, wl)| {
            Simulator::new(cfg.clone()).run(wl)
        });
        merge_results(parts)
    }
}

/// One worker per cell, capped by `FITGPP_THREADS` (when set and nonzero)
/// or the machine's available parallelism.
fn resolve_threads(cells: usize) -> usize {
    let cap = std::env::var("FITGPP_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        });
    cells.min(cap).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{JobClass, JobSpec};
    use crate::resources::ResourceVec;
    use crate::sched::policy::PolicyKind;

    fn rv(c: f64, r: f64, g: f64) -> ResourceVec {
        ResourceVec::new(c, r, g)
    }

    fn workload(n: u32) -> Workload {
        Workload::new(
            (0..n)
                .map(|i| {
                    JobSpec::new(
                        i,
                        if i % 3 == 0 { JobClass::Te } else { JobClass::Be },
                        rv(4.0 + (i % 3) as f64 * 8.0, 32.0, (i % 2) as f64 + 1.0),
                        (i as u64) / 2,
                        4 + (i as u64 % 13),
                        (i as u64) % 4,
                    )
                })
                .collect(),
        )
    }

    #[test]
    fn cluster_slices_partition_the_nodes() {
        let spec = ClusterSpec::tiny(7);
        let slices = split_cluster(&spec, 3);
        assert_eq!(slices.len(), 3);
        assert_eq!(
            slices.iter().map(|s| s.nodes.len()).collect::<Vec<_>>(),
            vec![3, 2, 2],
            "sizes differ by at most one"
        );
        let rebuilt: Vec<ResourceVec> =
            slices.iter().flat_map(|s| s.nodes.iter().copied()).collect();
        assert_eq!(rebuilt, spec.nodes, "concatenation is the original");
        // Clamping: more cells than nodes degenerates to one node each.
        assert_eq!(split_cluster(&spec, 100).len(), 7);
        assert_eq!(split_cluster(&spec, 0).len(), 1);
    }

    #[test]
    fn job_routing_is_by_id_mod_k() {
        let wl = workload(20);
        let shards = split_workload(&wl, 4);
        assert_eq!(shards.iter().map(|s| s.jobs.len()).sum::<usize>(), 20);
        for (cell, shard) in shards.iter().enumerate() {
            for spec in &shard.jobs {
                assert_eq!(spec.id.0 as usize % 4, cell);
            }
            // Submission order is preserved inside each cell.
            assert!(shard.jobs.windows(2).all(|w| w[0].submit <= w[1].submit));
        }
    }

    #[test]
    fn one_cell_is_the_plain_simulator() {
        let wl = workload(30);
        let cfg = SimConfig::new(ClusterSpec::tiny(2), PolicyKind::FitGpp { s: 4.0, p_max: Some(1) });
        let plain = Simulator::new(cfg.clone()).run(&wl);
        let sharded = ShardedSim::new(cfg, 1).run(&wl);
        assert_eq!(plain.records, sharded.records);
        assert_eq!(plain.metrics, sharded.metrics);
        assert_eq!(plain.makespan, sharded.makespan);
        assert_eq!(plain.peak_live, sharded.peak_live);
    }

    #[test]
    fn parallel_cells_match_serial_cells_exactly() {
        // The acceptance pin: a K-cell run is byte-identical whether its
        // cells execute serially or on the work-stealing pool.
        let wl = workload(60);
        let mk = |threads: usize| {
            let mut cfg = SimConfig::new(ClusterSpec::tiny(4), PolicyKind::FitGpp { s: 4.0, p_max: Some(1) });
            cfg.paranoid = true;
            ShardedSim::new(cfg, 4).with_threads(threads).run(&wl)
        };
        let serial = mk(1);
        let parallel = mk(4);
        assert_eq!(serial.records, parallel.records);
        assert_eq!(serial.metrics, parallel.metrics);
        assert_eq!(serial.makespan, parallel.makespan);
        assert_eq!(serial.unfinished, parallel.unfinished);
        assert_eq!(serial.peak_live, parallel.peak_live);
        assert_eq!(serial.sched_stats.ticks, parallel.sched_stats.ticks);
        assert_eq!(serial.sched_stats.completions, parallel.sched_stats.completions);
        assert_eq!(
            serial.sched_stats.preemption_signals,
            parallel.sched_stats.preemption_signals
        );
    }

    #[test]
    fn merged_result_accounts_for_every_job() {
        let wl = workload(60);
        let cfg = SimConfig::new(ClusterSpec::tiny(4), PolicyKind::Fifo);
        let sharded = ShardedSim::new(cfg, 3).with_threads(2).run(&wl);
        assert_eq!(sharded.records.len(), 60, "every job keeps a record");
        assert_eq!(sharded.metrics.jobs_seen, 60);
        assert_eq!(sharded.unfinished, 0, "cells drain independently");
        assert_eq!(sharded.sched_stats.completions, 60);
        // Records come back in global id order despite the mod-K split.
        assert!(sharded.records.windows(2).all(|w| w[0].id < w[1].id));
    }

    #[test]
    fn cell_configs_slice_nodes_and_decorrelate_seeds() {
        let mut cfg = SimConfig::new(ClusterSpec::tiny(5), PolicyKind::Rand);
        cfg.seed = 100;
        let sharded = ShardedSim::new(cfg, 2);
        let cfgs = sharded.cell_configs();
        assert_eq!(cfgs.len(), 2);
        assert_eq!(cfgs[0].cluster.nodes.len(), 3);
        assert_eq!(cfgs[1].cluster.nodes.len(), 2);
        assert_eq!(cfgs[0].seed, 100, "cell 0 keeps the base seed");
        assert_eq!(cfgs[1].seed, 101);
    }
}
