//! Discrete-time simulator: streams an
//! [`ArrivalSource`](crate::workload::source::ArrivalSource) through a
//! [`Scheduler`](crate::sched::Scheduler) (§4.1: "the job scheduler decides resource allocation at
//! every simulated minute").
//!
//! ## Streaming core over the control-plane protocol
//!
//! One core loop ([`SimSession::round`]) drives a
//! [`ClusterController`] — the same command/event facade the live
//! executor uses — pulling arrivals *lazily* from the source through a
//! bounded lookahead window into the scheduler's
//! [`EventClock`](crate::sched::EventClock), translating any attached
//! [`ScenarioScript`] (timed cancellations, node failures/drains/resizes,
//! the TE-patience rule — see [`scenario`]) into
//! [`SchedulerCommand`](crate::sched::control::SchedulerCommand)s applied
//! between rounds, and retiring each job out of the slab
//! [`JobTable`](crate::job_table::JobTable) the tick it completes, folding
//! its outcome into a [`StreamingMetrics`] sink. Every observable state
//! change is emitted as a
//! [`SchedulerEvent`](crate::sched::control::SchedulerEvent) to any
//! subscribers passed to [`Simulator::run_with`]. Resident state is therefore O(live jobs) —
//! queued + running + draining — not O(total jobs), which is what lets a
//! million-job trace replay in bounded memory (`SimResult::peak_live` is
//! the asserted high-water counter). Full per-job records stay available
//! behind [`SimConfig::record_jobs`] (the default, and the equivalence
//! oracle's mode): a streamed run with records on is byte-identical to the
//! old materialize-everything driver.
//!
//! Both engines share the loop:
//!
//! * [`SimEngine::EventHorizon`] (default) — after each tick, if the
//!   scheduler is quiescent, fast-forwards to the next *event horizon*
//!   (earliest of the next arrival — resident or still inside the source —
//!   next completion/grace expiry, and the engine's stopping caps) in a
//!   single [`Scheduler::burn_many`](crate::sched::Scheduler::burn_many) call instead of ticking minute by
//!   minute.
//! * [`SimEngine::PerMinute`] — the reference drive mode, one
//!   [`Scheduler::tick`](crate::sched::Scheduler::tick) per simulated minute. Kept as the equivalence
//!   oracle: `rust/tests/engine_equivalence.rs` and
//!   `rust/tests/streaming_equivalence.rs` assert both drive modes and all
//!   source types produce byte-identical records.
//!
//! The simulator is deterministic: (source, config, seed) → identical
//! results, whichever engine runs — which is what makes every number in
//! EXPERIMENTS.md reproducible.

// Perf-sensitive tree: silent copies and churny buffer idioms are bugs
// here, not style nits (the hot path is pinned allocation-free by the
// perf gate).
#![deny(
    clippy::redundant_clone,
    clippy::large_enum_variant,
    clippy::vec_init_then_push
)]

pub mod cells;
pub mod scenario;

use crate::cluster::{ClusterSpec, Placement};
use crate::job::{Job, JobClass, JobId, JobState, TenantId};
use crate::metrics::{
    tenant_table, IntervalsReport, PreemptionReport, SlowdownReport, StreamingMetrics,
};
use crate::resources::ResourceVec;
use crate::sched::admission::DisciplineKind;
use crate::sched::control::{ClusterController, EventSubscriber, SchedulerCommand};
use crate::sched::policy::PolicyKind;
use crate::sched::predict::EstimatorKind;
use crate::sched::{SchedConfig, SchedStats};
use crate::sim::scenario::{ScenarioDriver, ScenarioScript};
use crate::util::bin::{BinReader, BinWriter};
use crate::util::json::Json;
use anyhow::bail;
use crate::util::table::Table;
use crate::workload::source::{ArrivalSource, WorkloadSource};
use crate::workload::Workload;
use crate::Minutes;

/// Which driver advances simulated time. Both engines share
/// [`Scheduler::tick`](crate::sched::Scheduler::tick); they differ only in how many quiescent minutes they
/// step through one at a time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimEngine {
    /// Fast-forward quiescent spans to the next event horizon (default).
    #[default]
    EventHorizon,
    /// The original reference loop: one tick per simulated minute.
    PerMinute,
}

/// Full configuration of one simulation run.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Cluster to simulate.
    pub cluster: ClusterSpec,
    /// Scheduling/preemption policy under test.
    pub policy: PolicyKind,
    /// Admission queue discipline for the shared/BE queue
    /// ([`DisciplineKind::Fifo`] by default — byte-identical to the
    /// pre-admission-layer simulator).
    pub discipline: DisciplineKind,
    /// Occupied-Size quota applied to every tenant with no explicit
    /// `SetQuota` entry (`None` = unlimited, the default).
    pub default_quota: Option<f64>,
    /// Node-selection rule for placements.
    pub placement: Placement,
    /// Whether draining jobs keep making progress (§2 ablation).
    pub progress_during_grace: bool,
    /// Seed for the policy RNG (RAND victims, FitGpp fallback).
    pub seed: u64,
    /// Runtime estimator feeding the prediction-aware policies
    /// ([`EstimatorKind::Oracle`] by default — byte-identical to the
    /// pre-prediction simulator for every policy that ignores
    /// predictions).
    pub estimator: EstimatorKind,
    /// Time-advance engine (event-horizon by default; per-minute is the
    /// equivalence oracle).
    pub engine: SimEngine,
    /// Keep ticking after the last arrival until every job completes
    /// (default). With `false`, stop at the last arrival + `tail_ticks`.
    pub drain: bool,
    /// Extra ticks after last arrival when `drain == false`.
    pub tail_ticks: Minutes,
    /// Hard safety cap on total ticks.
    pub max_ticks: Minutes,
    /// Run invariant checks every tick (tests).
    pub paranoid: bool,
    /// Keep full per-job [`JobRecord`]s (default). With `false`, retiring
    /// jobs are folded into the [`StreamingMetrics`] sink only, and the
    /// run's memory is O(live jobs) — the streaming/scale mode.
    pub record_jobs: bool,
    /// How many minutes ahead of `now` arrivals are pulled from the source
    /// into the clock. `0` (default) pulls each arrival exactly on its
    /// submission minute — the smallest possible live set; larger windows
    /// trade a bigger resident prefix for fewer source interactions.
    /// Ignored (clamped to 0) for feedback-driven sources — see
    /// [`ArrivalSource::feedback_driven`].
    pub arrival_lookahead: Minutes,
    /// Deterministic control-plane injections (cancellations, node
    /// failures/drains/resizes, the TE-patience kill rule) replayed against
    /// the run. `None` (default) — and an *empty* script alike — leaves
    /// results byte-identical to a scenario-free run.
    pub scenario: Option<ScenarioScript>,
}

impl SimConfig {
    /// Defaults matching the paper's §4 setup: best-fit placement, no
    /// progress during grace, drain to completion, event-horizon engine.
    pub fn new(cluster: ClusterSpec, policy: PolicyKind) -> Self {
        SimConfig {
            cluster,
            policy,
            discipline: DisciplineKind::Fifo,
            default_quota: None,
            placement: Placement::BestFit,
            progress_during_grace: false,
            seed: 0x5EED,
            estimator: EstimatorKind::Oracle,
            engine: SimEngine::default(),
            drain: true,
            tail_ticks: 0,
            max_ticks: 10_000_000,
            paranoid: false,
            record_jobs: true,
            arrival_lookahead: 0,
            scenario: None,
        }
    }
}

/// Immutable per-job outcome captured at the end of a run.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    /// The job's identifier.
    pub id: JobId,
    /// TE or BE.
    pub class: JobClass,
    /// Requested resources.
    pub demand: ResourceVec,
    /// Submission tick.
    pub submit: Minutes,
    /// Required execution time.
    pub exec_time: Minutes,
    /// Declared grace period.
    pub grace_period: Minutes,
    /// First tick the job ran (None if it never started).
    pub first_start: Option<Minutes>,
    /// Completion tick (None if unfinished at cut-off).
    pub finished_at: Option<Minutes>,
    /// How many times the job was preempted.
    pub preemptions: u32,
    /// Node-failure evictions the job suffered (control plane; not
    /// preemptions).
    pub evictions: u32,
    /// Completed vacate→restart intervals (Table 2).
    pub resched_intervals: Vec<Minutes>,
    /// Eq. 5 slowdown rate.
    pub slowdown: f64,
    /// True when the job was cancelled by the control plane (then
    /// `finished_at` is `None` and the job is excluded from slowdown,
    /// interval, and preemption statistics).
    pub cancelled: bool,
    /// The tenant the job belonged to (admission-layer identity; keys the
    /// per-tenant metrics map).
    pub tenant: TenantId,
}

impl JobRecord {
    /// Capture a job's outcome at its current state. Used by the simulator
    /// when a job retires (and at cut-off for unfinished jobs) and by the
    /// live executor's final report; for an unfinished job `finished_at`
    /// is `None` and `slowdown` is the accrued-wait lower bound (Eq. 5).
    pub fn from_job(j: &Job) -> Self {
        JobRecord {
            id: j.id(),
            class: j.spec.class,
            demand: j.spec.demand,
            submit: j.spec.submit,
            exec_time: j.spec.exec_time,
            grace_period: j.spec.grace_period,
            first_start: j.first_start,
            finished_at: j.finished_at,
            preemptions: j.preemptions,
            evictions: j.evictions,
            resched_intervals: j.resched_intervals.clone(),
            slowdown: j.slowdown(),
            cancelled: j.state == JobState::Cancelled,
            tenant: j.spec.tenant,
        }
    }

    /// Serialize the record for a session snapshot.
    pub(crate) fn snapshot_bin(&self, w: &mut BinWriter) {
        w.u32(self.id.0);
        w.u8(self.class.tag());
        self.demand.snapshot_bin(w);
        w.u64(self.submit);
        w.u64(self.exec_time);
        w.u64(self.grace_period);
        w.opt_u64(self.first_start);
        w.opt_u64(self.finished_at);
        w.u32(self.preemptions);
        w.u32(self.evictions);
        w.seq(self.resched_intervals.len());
        for m in &self.resched_intervals {
            w.u64(*m);
        }
        w.f64(self.slowdown);
        w.bool(self.cancelled);
        w.u32(self.tenant.0);
    }

    /// Inverse of [`JobRecord::snapshot_bin`].
    pub(crate) fn restore_bin(r: &mut BinReader) -> anyhow::Result<Self> {
        let id = JobId(r.u32()?);
        let class = JobClass::from_tag(r.u8()?)?;
        let demand = ResourceVec::restore_bin(r)?;
        let submit = r.u64()?;
        let exec_time = r.u64()?;
        let grace_period = r.u64()?;
        let first_start = r.opt_u64()?;
        let finished_at = r.opt_u64()?;
        let preemptions = r.u32()?;
        let evictions = r.u32()?;
        let n = r.seq()?;
        let mut resched_intervals = Vec::with_capacity(n);
        for _ in 0..n {
            resched_intervals.push(r.u64()?);
        }
        Ok(JobRecord {
            id,
            class,
            demand,
            submit,
            exec_time,
            grace_period,
            first_start,
            finished_at,
            preemptions,
            evictions,
            resched_intervals,
            slowdown: r.f64()?,
            cancelled: r.bool()?,
            tenant: TenantId(r.u32()?),
        })
    }
}

/// Everything a run produced.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Policy that produced this result.
    pub policy: PolicyKind,
    /// Per-job outcomes, in job-id (submission) order. Empty when the run
    /// was streamed with [`SimConfig::record_jobs`] off.
    pub records: Vec<JobRecord>,
    /// The streaming metrics sink every retiring job was folded into
    /// (always populated, records on or off; mergeable across runs).
    pub metrics: StreamingMetrics,
    /// Aggregate scheduler counters.
    pub sched_stats: SchedStats,
    /// Tick at which the simulation stopped.
    pub makespan: Minutes,
    /// Number of jobs still unfinished at the end (0 when draining).
    pub unfinished: usize,
    /// High-water mark of the resident job table — the live-set bound the
    /// scale bench and CI smoke assert on.
    pub peak_live: usize,
    /// Whether full records were kept (selects exact vs sketch-backed
    /// reports).
    pub record_jobs: bool,
    /// `Finished` records folded into the runtime estimator over the run
    /// (the CI prediction-smoke greps this; equals completions whenever an
    /// estimator is attached, which is always).
    pub prediction_updates: u64,
}

impl SimResult {
    /// Slowdown rates of completed jobs of `class` (Eq. 5).
    pub fn slowdowns(&self, class: JobClass) -> Vec<f64> {
        self.records
            .iter()
            .filter(|r| r.class == class && r.finished_at.is_some())
            .map(|r| r.slowdown)
            .collect()
    }

    /// Re-scheduling intervals (vacate → restart) in minutes, all
    /// non-cancelled jobs pooled (Table 2; matches the streaming sink,
    /// which never sees cancelled jobs' intervals).
    pub fn resched_intervals(&self) -> Vec<f64> {
        self.records
            .iter()
            .filter(|r| !r.cancelled)
            .flat_map(|r| r.resched_intervals.iter().map(|m| *m as f64))
            .collect()
    }

    /// Fraction of non-cancelled jobs preempted at least once (Table 3).
    pub fn preempted_fraction(&self) -> f64 {
        let mut n = 0usize;
        let mut p = 0usize;
        for r in &self.records {
            if r.cancelled {
                continue;
            }
            n += 1;
            if r.preemptions > 0 {
                p += 1;
            }
        }
        if n == 0 {
            return 0.0;
        }
        p as f64 / n as f64
    }

    /// Fractions of non-cancelled jobs preempted exactly 1, exactly 2,
    /// and ≥3 times (Table 4).
    pub fn preemption_histogram(&self) -> [f64; 3] {
        let mut n = 0usize;
        let mut h = [0usize; 3];
        for r in &self.records {
            if r.cancelled {
                continue;
            }
            n += 1;
            match r.preemptions {
                0 => {}
                1 => h[0] += 1,
                2 => h[1] += 1,
                _ => h[2] += 1,
            }
        }
        let n = n.max(1) as f64;
        [h[0] as f64 / n, h[1] as f64 / n, h[2] as f64 / n]
    }

    /// Control-plane cancellations `(te, be)` — always sourced from the
    /// metrics sink, which counts them exactly in both record modes.
    pub fn cancelled(&self) -> (u64, u64) {
        (self.metrics.cancelled.te, self.metrics.cancelled.be)
    }

    /// Slowdown percentiles: exact (from records) when `record_jobs` was
    /// on, sketch-backed (≤ ~0.5% relative error) when streamed without
    /// records.
    pub fn slowdown_report(&self) -> SlowdownReport {
        if self.record_jobs {
            SlowdownReport::from_result(self)
        } else {
            self.metrics.slowdown_report()
        }
    }

    /// Re-scheduling-interval percentiles (exact or sketch-backed, as
    /// above).
    pub fn intervals_report(&self) -> IntervalsReport {
        if self.record_jobs {
            IntervalsReport::from_result(self)
        } else {
            self.metrics.intervals_report()
        }
    }

    /// Preemption statistics (exact in both modes — counters, not
    /// sketches).
    pub fn preemption_report(&self) -> PreemptionReport {
        if self.record_jobs {
            PreemptionReport::from_result(self)
        } else {
            self.metrics.preemption_report()
        }
    }

    /// One-run table matching the layout of the paper's Table 1 row.
    pub fn summary_table(&self) -> String {
        let r = self.slowdown_report();
        let mut t = Table::new(
            &format!("{} — slowdown percentiles", self.policy.name()),
            &["class", "50th", "95th", "99th"],
        );
        t.row(vec![
            "TE".into(),
            format!("{:.2}", r.te.p50),
            format!("{:.2}", r.te.p95),
            format!("{:.2}", r.te.p99),
        ]);
        t.row(vec![
            "BE".into(),
            format!("{:.2}", r.be.p50),
            format!("{:.2}", r.be.p95),
            format!("{:.2}", r.be.p99),
        ]);
        t.to_text()
    }

    /// Per-tenant fairness table (sketch-backed; one row per tenant seen).
    pub fn tenant_table(&self) -> String {
        tenant_table(
            &format!("{} — per-tenant slowdown percentiles", self.policy.name()),
            &self.metrics.tenants,
        )
        .to_text()
    }

    /// Number of distinct tenants observed by the run.
    pub fn tenants_seen(&self) -> usize {
        self.metrics.tenants.len()
    }

    /// Machine-readable dump for plotting scripts.
    pub fn to_json(&self) -> Json {
        let r = self.slowdown_report();
        let iv = self.intervals_report();
        let pr = self.preemption_report();
        Json::obj(vec![
            ("policy", Json::str(&self.policy.name())),
            ("makespan", Json::num(self.makespan as f64)),
            ("unfinished", Json::num(self.unfinished as f64)),
            ("jobs_seen", Json::num(self.metrics.jobs_seen as f64)),
            ("peak_live", Json::num(self.peak_live as f64)),
            ("prediction_updates", Json::num(self.prediction_updates as f64)),
            ("tenants", self.metrics.tenants_json()),
            (
                "cancelled",
                Json::obj(vec![
                    ("te", Json::num(self.metrics.cancelled.te as f64)),
                    ("be", Json::num(self.metrics.cancelled.be as f64)),
                ]),
            ),
            (
                "slowdown",
                Json::obj(vec![
                    ("te", r.te.to_json()),
                    ("be", r.be.to_json()),
                ]),
            ),
            (
                "intervals",
                Json::obj(vec![
                    ("p50", Json::num(iv.p50)),
                    ("p75", Json::num(iv.p75)),
                    ("p95", Json::num(iv.p95)),
                    ("p99", Json::num(iv.p99)),
                    ("count", Json::num(iv.count as f64)),
                ]),
            ),
            (
                "preemption",
                Json::obj(vec![
                    ("fraction_preempted", Json::num(pr.fraction_preempted)),
                    ("hist1", Json::num(pr.hist[0])),
                    ("hist2", Json::num(pr.hist[1])),
                    ("hist3plus", Json::num(pr.hist[2])),
                    ("signals", Json::num(self.sched_stats.preemption_signals as f64)),
                    ("fallback_plans", Json::num(self.sched_stats.fallback_plans as f64)),
                ]),
            ),
        ])
    }
}

/// The simulator driver.
pub struct Simulator {
    cfg: SimConfig,
}

impl Simulator {
    /// Build a simulator for one configuration.
    pub fn new(cfg: SimConfig) -> Self {
        Simulator { cfg }
    }

    /// Run a materialized `workload` to completion and collect results —
    /// streams it through the back-compat [`WorkloadSource`] adapter.
    pub fn run(&self, workload: &Workload) -> SimResult {
        self.run_source(&mut WorkloadSource::new(workload))
    }

    /// Run any pull-based [`ArrivalSource`] to completion with no extra
    /// event subscribers. [`Simulator::run`] and every sweep cell route
    /// through it.
    pub fn run_source(&self, source: &mut dyn ArrivalSource) -> SimResult {
        self.run_with(source, Vec::new())
    }

    /// Run a source with additional [`EventSubscriber`]s attached (a JSONL
    /// event log, an in-memory collector, …). This is the primary entry
    /// point: both [`SimEngine`]s are drive modes of one core loop over the
    /// [`ClusterController`] protocol; the event-horizon mode additionally
    /// fast-forwards quiescent spans. Subscribers are dropped (flushing
    /// any buffered output) before the result returns.
    pub fn run_with(
        &self,
        source: &mut dyn ArrivalSource,
        subscribers: Vec<Box<dyn EventSubscriber>>,
    ) -> SimResult {
        let mut session = SimSession::new(self.cfg.clone(), subscribers);
        session.run_to_completion(source);
        session.finish(source)
    }
}

/// One in-flight simulation, reified: the streaming core loop's complete
/// state, steppable one scheduling round at a time. [`Simulator::run_with`]
/// drives a session straight to completion; the wire service
/// ([`crate::serve`]) instead steps sessions under wall-clock pacing,
/// applies commands arriving over connections between rounds, snapshots
/// them at round boundaries, and restores them after a kill. A snapshot
/// captures everything the loop needs, so restore + continue is
/// byte-identical to never having stopped (pinned by
/// `rust/tests/serve_snapshot.rs`).
pub struct SimSession {
    cfg: SimConfig,
    ctl: ClusterController,
    scenario: Option<ScenarioDriver>,
    /// Records of retired jobs so far (kept in the snapshot: the final
    /// report needs pre-snapshot retirees to match an uninterrupted run).
    records: Vec<JobRecord>,
    /// Latest submission pulled so far; equals the workload's final
    /// submission once the source is exhausted.
    last_submit: Minutes,
    /// The minute the next round will simulate.
    now: Minutes,
    /// Arrivals pulled from the source so far — replayed against a fresh
    /// source on restore (the source itself stays outside the snapshot).
    pulled: u64,
    fast_forward: bool,
    done: bool,
}

impl SimSession {
    /// Build a session at minute 0: controller, primed scenario driver,
    /// attached subscribers.
    pub fn new(cfg: SimConfig, subscribers: Vec<Box<dyn EventSubscriber>>) -> Self {
        let mut sched_cfg = SchedConfig::new(cfg.policy);
        sched_cfg.discipline = cfg.discipline;
        sched_cfg.default_quota = cfg.default_quota;
        sched_cfg.placement = cfg.placement;
        sched_cfg.progress_during_grace = cfg.progress_during_grace;
        sched_cfg.seed = cfg.seed;
        sched_cfg.estimator = cfg.estimator;
        let mut ctl = ClusterController::new(&cfg.cluster, sched_cfg);
        ctl.sched.paranoid = cfg.paranoid;
        for sub in subscribers {
            ctl.subscribe(sub);
        }
        let scenario = cfg.scenario.as_ref().map(|s| ScenarioDriver::new(s.clone()));
        if let Some(driver) = &scenario {
            // Every timed command minute becomes a clock control entry so
            // the fast-forward target can never cross one.
            driver.prime(&mut ctl.sched.clock);
        }
        let fast_forward = cfg.engine == SimEngine::EventHorizon;
        SimSession {
            cfg,
            ctl,
            scenario,
            records: Vec::new(),
            last_submit: 0,
            now: 0,
            pulled: 0,
            fast_forward,
            done: false,
        }
    }

    /// The minute the next round will simulate.
    pub fn now(&self) -> Minutes {
        self.now
    }

    /// True once a stop condition fired; further rounds are no-ops.
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Clear the done latch. The wire service parks a drained session
    /// instead of tearing it down; a late-arriving command (say, a fresh
    /// submission into the now-idle cluster) reopens it and rounds
    /// resume. If nothing actually changed, the next round simply
    /// re-latches.
    pub fn reopen(&mut self) {
        self.done = false;
    }

    /// Jobs retired (completed or cancelled) so far.
    pub fn records_retired(&self) -> usize {
        self.records.len()
    }

    /// Apply a control-plane command at the current minute, exactly as a
    /// scenario script would between rounds. Used by the wire service for
    /// commands arriving over connections.
    pub fn command(&mut self, cmd: SchedulerCommand) {
        self.ctl.command(self.now, cmd);
    }

    /// One iteration of the shared streaming core loop (one scheduling
    /// round; under fast-forward possibly followed by a bulk burn to the
    /// next event horizon). Returns `false` once the run is over. Every
    /// iteration:
    ///
    /// 1. **Pull** — arrivals whose submit minute is within
    ///    `now + arrival_lookahead` move from the source into the job
    ///    table and the clock's arrival heap.
    /// 2. **Pop + tick** — arrivals due this minute leave the heap and one
    ///    [`Scheduler::tick`](crate::sched::Scheduler::tick) runs (exactly as the paper describes the
    ///    scheduler operating).
    /// 3. **Retire** — jobs that completed this tick leave the job table;
    ///    each outcome is folded into the [`StreamingMetrics`] sink (and
    ///    kept as a [`JobRecord`] when `record_jobs` is on), and the
    ///    source is notified so closed-loop users can schedule their next
    ///    trial.
    /// 4. **Stop check** — mirrors the pre-streaming driver exactly:
    ///    arrivals are exhausted when the source is done *and* the clock's
    ///    heap is empty, at which point `last_submit` (the max pulled) is
    ///    the true final submission.
    ///
    /// With `fast_forward` set (the event-horizon mode), a tick after which
    /// the scheduler is [quiescent](crate::sched::Scheduler::quiescent) — and nothing
    /// vacated in the tick just executed, since a vacated job becomes
    /// admittable one tick later — advances the span until the earliest of
    ///
    /// * the next arrival (clock heap peek *or* the source's
    ///   [`peek_submit`](ArrivalSource::peek_submit) for not-yet-pulled
    ///   jobs),
    /// * the next internal event — completion or grace expiry
    ///   ([`Scheduler::next_internal_at`](crate::sched::Scheduler::next_internal_at), a clock heap peek), and
    /// * the engine's stopping caps (`max_ticks`, the no-drain tail cutoff)
    ///
    /// in one [`Scheduler::burn_many`](crate::sched::Scheduler::burn_many) call. Quiescent spans therefore cost
    /// O(live jobs) once instead of per minute, and the results are
    /// byte-identical to the per-minute drive mode (see
    /// `rust/tests/engine_equivalence.rs`).
    pub fn round(&mut self, source: &mut dyn ArrivalSource) -> bool {
        if self.done {
            return false;
        }
        // Feedback-driven (closed-loop) sources may schedule a new arrival
        // earlier than one already visible: pulling ahead would break the
        // monotone-submit contract, so their lookahead is pinned to zero.
        let lookahead = if source.feedback_driven() {
            0
        } else {
            self.cfg.arrival_lookahead
        };
        let now = self.now;

        // ---- 1: pull arrivals inside the lookahead window ----------
        while let Some(at) = source.peek_submit() {
            if at > now.saturating_add(lookahead) {
                break;
            }
            let spec = source.next_job().expect("peeked arrival must be yieldable");
            debug_assert!(spec.submit == at && at >= now, "source out of order");
            debug_assert!(spec.submit >= self.last_submit, "submits must be monotone");
            self.pulled += 1;
            self.last_submit = self.last_submit.max(spec.submit);
            self.ctl.stage_arrival(spec);
        }

        // ---- 2: control plane — commands due this minute -----------
        if let Some(driver) = &mut self.scenario {
            self.ctl.sched.clock.pop_controls_due(now);
            let (cmds, wake) = driver.due(now, &self.ctl.sched, &self.ctl.jobs);
            for cmd in cmds {
                self.ctl.command(now, cmd);
            }
            for at in wake {
                self.ctl.sched.clock.push_control(at);
            }
        }

        // ---- 3: one scheduling round (arrivals pop inside) ---------
        let out = self.ctl.step(now);
        if let Some(driver) = &mut self.scenario {
            for at in driver.watch_arrivals(now, &out.arrivals, &self.ctl.jobs) {
                self.ctl.sched.clock.push_control(at);
            }
        }

        // ---- 4: retire into records, notify the source -------------
        // Cancellations first (they were applied before the round);
        // closed-loop users treat a kill like a completion and
        // schedule their next trial.
        for rec in out.cancelled {
            source.on_job_finished(rec.id, now);
            if self.cfg.record_jobs {
                self.records.push(rec);
            }
        }
        for rec in out.finished {
            source.on_job_finished(rec.id, now);
            if self.cfg.record_jobs {
                self.records.push(rec);
            }
        }
        self.now = now + 1;
        let now = self.now;

        // ---- 5: stop conditions ------------------------------------
        let no_more_arrivals = source.done() && !self.ctl.sched.clock.arrivals_pending();
        if no_more_arrivals && now > self.last_submit {
            if self.cfg.drain {
                if self.ctl.idle() {
                    self.done = true;
                    return false;
                }
            } else if now > self.last_submit + self.cfg.tail_ticks {
                self.done = true;
                return false;
            }
        }
        if now >= self.cfg.max_ticks {
            self.done = true;
            return false;
        }

        // ---- fast-forward to the next event horizon ----------------
        if self.fast_forward && out.tick.vacated.is_empty() && self.ctl.quiescent() {
            // Latest tick the per-minute mode could still execute
            // before one of its break conditions fires.
            let mut target = self.cfg.max_ticks.saturating_sub(1);
            if !self.cfg.drain && no_more_arrivals {
                target = target.min(self.last_submit + self.cfg.tail_ticks);
            }
            if let Some(at) = self.ctl.next_internal_at() {
                target = target.min(at);
            }
            if let Some(at) = self.ctl.sched.clock.next_arrival_at() {
                target = target.min(at);
            }
            if let Some(at) = self.ctl.sched.clock.next_control_at() {
                // Pending command injections (or deferred-cancel
                // retries) pin the horizon exactly like arrivals.
                target = target.min(at);
            }
            if let Some(at) = source.peek_submit() {
                // Next unpulled arrival: stop there so the pull loop
                // picks it up on its submission minute.
                target = target.min(at);
            }
            if target > now {
                self.ctl.burn_many(target - now);
                self.now = target;
            }
        }
        true
    }

    /// Drive rounds until a stop condition fires.
    pub fn run_to_completion(&mut self, source: &mut dyn ArrivalSource) {
        while self.round(source) {}
    }

    /// Drive rounds until the session reaches (or, under fast-forward,
    /// overshoots) `minute`, or the run ends — whichever comes first.
    /// Leaves the session at a round boundary, the only place a snapshot
    /// may be taken.
    pub fn run_until(&mut self, source: &mut dyn ArrivalSource, minute: Minutes) {
        while self.now < minute && self.round(source) {}
    }

    /// Assemble the result: fold unfinished resident jobs (and any jobs
    /// the source still holds after a `max_ticks` cut-off — the
    /// materialized driver recorded those as never-started, so the
    /// streamed one must too) into the sink, then sort records into job-id
    /// order for byte-compatibility with the materialized path. Cancelled
    /// jobs were retired (and recorded) at cancellation time and are *not*
    /// unfinished. Attached subscribers are dropped here (flushing any
    /// buffered output).
    pub fn finish(self, source: &mut dyn ArrivalSource) -> SimResult {
        let SimSession {
            cfg,
            ctl,
            mut records,
            now,
            ..
        } = self;
        let (sched, mut jobs, mut metrics) = ctl.into_parts();
        // Counters are lazily accounted (see `Job::sync`): settle every
        // still-resident job up to the cut-off minute so accrued-wait
        // slowdowns and records read exact values.
        jobs.settle_all(now);
        let mut unfinished = 0usize;
        for job in jobs.iter() {
            debug_assert!(job.state != JobState::Done, "Done jobs retire eagerly");
            unfinished += 1;
            let rec = JobRecord::from_job(job);
            metrics.observe(&rec);
            if cfg.record_jobs {
                records.push(rec);
            }
        }
        while let Some(spec) = source.next_job() {
            unfinished += 1;
            let rec = JobRecord::from_job(&Job::new(spec));
            metrics.observe(&rec);
            if cfg.record_jobs {
                records.push(rec);
            }
        }
        records.sort_by_key(|r| r.id);
        SimResult {
            policy: cfg.policy,
            records,
            metrics,
            sched_stats: sched.stats.clone(),
            makespan: now,
            unfinished,
            peak_live: jobs.peak_live(),
            record_jobs: cfg.record_jobs,
            prediction_updates: sched.estimator().updates(),
        }
    }

    /// The configuration identity burned into every snapshot: two runs
    /// with equal fingerprints make identical decisions, so restoring
    /// under a different config is rejected instead of silently
    /// diverging.
    fn fingerprint(cfg: &SimConfig) -> String {
        format!("{cfg:?}")
    }

    /// Serialize the session's complete state. Must be called at a round
    /// boundary (where [`SimSession::round`] returned); the payload is
    /// raw — the serve layer wraps it in a versioned, checksummed
    /// envelope ([`crate::serve::snapshot`]).
    pub fn snapshot_bin(&self, w: &mut BinWriter) {
        w.str(&Self::fingerprint(&self.cfg));
        w.u64(self.now);
        w.u64(self.last_submit);
        w.u64(self.pulled);
        w.bool(self.done);
        w.seq(self.records.len());
        for rec in &self.records {
            rec.snapshot_bin(w);
        }
        self.ctl.snapshot_bin(w);
        w.bool(self.scenario.is_some());
        if let Some(driver) = &self.scenario {
            driver.snapshot_bin(w);
        }
    }

    /// Rebuild a session from a snapshot payload, a configuration equal
    /// to the one snapshotted, fresh subscribers, and a fresh instance of
    /// the same arrival source. The source is fast-forwarded past every
    /// arrival the snapshot already consumed (those jobs live on in the
    /// job table and records); feedback-driven sources carry state the
    /// snapshot cannot capture and are rejected. Continuing the restored
    /// session is byte-identical to never having stopped.
    pub fn restore_bin(
        cfg: SimConfig,
        r: &mut BinReader,
        subscribers: Vec<Box<dyn EventSubscriber>>,
        source: &mut dyn ArrivalSource,
    ) -> anyhow::Result<SimSession> {
        if source.feedback_driven() {
            bail!(
                "cannot restore a run driven by a feedback-coupled (closed-loop) source: \
                 the source's own state is not part of the snapshot"
            );
        }
        let fingerprint = Self::fingerprint(&cfg);
        let mut s = SimSession::new(cfg, subscribers);
        let saved = r.str()?;
        if saved != fingerprint {
            bail!(
                "snapshot was taken under a different configuration:\n  snapshot: {saved}\n  current:  {fingerprint}"
            );
        }
        s.now = r.u64()?;
        s.last_submit = r.u64()?;
        s.pulled = r.u64()?;
        s.done = r.bool()?;
        let n = r.seq()?;
        s.records = Vec::with_capacity(n);
        for _ in 0..n {
            s.records.push(JobRecord::restore_bin(r)?);
        }
        s.ctl.restore_bin(r)?;
        if r.bool()? != s.scenario.is_some() {
            bail!("snapshot corrupt: scenario presence does not match the configuration");
        }
        if let Some(driver) = &mut s.scenario {
            driver.restore_bin(r)?;
        }
        for i in 0..s.pulled {
            if source.next_job().is_none() {
                bail!(
                    "source ran dry after {i} of {} already-consumed arrivals — \
                     this is not the source the snapshot was taken against",
                    s.pulled
                );
            }
        }
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::JobSpec;
    use crate::workload::Workload;

    fn rv(c: f64, r: f64, g: f64) -> ResourceVec {
        ResourceVec::new(c, r, g)
    }

    fn wl(specs: Vec<JobSpec>) -> Workload {
        Workload::new(specs)
    }

    #[test]
    fn empty_workload_terminates() {
        let cfg = SimConfig::new(ClusterSpec::tiny(1), PolicyKind::Fifo);
        let res = Simulator::new(cfg).run(&wl(vec![]));
        assert_eq!(res.records.len(), 0);
        assert_eq!(res.unfinished, 0);
    }

    #[test]
    fn drain_completes_everything() {
        let mut cfg = SimConfig::new(ClusterSpec::tiny(2), PolicyKind::Fifo);
        cfg.paranoid = true;
        let specs = (0..20)
            .map(|i| {
                JobSpec::new(i, if i % 3 == 0 { JobClass::Te } else { JobClass::Be },
                    rv(8.0, 64.0, 2.0), (i as u64) / 2, 7, 1)
            })
            .collect();
        let res = Simulator::new(cfg).run(&wl(specs));
        assert_eq!(res.unfinished, 0);
        assert!(res.records.iter().all(|r| r.finished_at.is_some()));
        assert!(res.records.iter().all(|r| r.slowdown >= 1.0), "slowdown >= 1 always");
    }

    #[test]
    fn determinism_same_seed_same_result() {
        let specs: Vec<JobSpec> = (0..40)
            .map(|i| {
                JobSpec::new(i, if i % 4 == 0 { JobClass::Te } else { JobClass::Be },
                    rv(4.0 + (i % 3) as f64 * 8.0, 32.0, (i % 2) as f64 + 1.0),
                    (i as u64) / 3, 5 + (i as u64 % 13), (i as u64) % 4)
            })
            .collect();
        let mk = || {
            let mut cfg = SimConfig::new(ClusterSpec::tiny(2), PolicyKind::Rand);
            cfg.seed = 99;
            Simulator::new(cfg).run(&wl(specs.clone()))
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.makespan, b.makespan);
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.finished_at, rb.finished_at);
            assert_eq!(ra.preemptions, rb.preemptions);
        }
    }

    #[test]
    fn no_drain_stops_at_tail() {
        let mut cfg = SimConfig::new(ClusterSpec::tiny(1), PolicyKind::Fifo);
        cfg.drain = false;
        cfg.tail_ticks = 2;
        // A job that would run for 1000 minutes.
        let res = Simulator::new(cfg).run(&wl(vec![JobSpec::new(
            0, JobClass::Be, rv(1.0, 1.0, 0.0), 0, 1000, 0,
        )]));
        assert_eq!(res.unfinished, 1);
        assert!(res.makespan <= 4);
    }

    #[test]
    fn engines_agree_on_crafted_workload() {
        // Preemptions, grace drains, re-queues, and a long drain tail: the
        // two engines must agree on every record and the makespan.
        let specs: Vec<JobSpec> = (0..30)
            .map(|i| {
                JobSpec::new(
                    i,
                    if i % 3 == 0 { JobClass::Te } else { JobClass::Be },
                    rv(6.0 + (i % 4) as f64 * 8.0, 48.0, (i % 3) as f64),
                    (i as u64) / 2,
                    4 + (i as u64 % 17) * 3,
                    (i as u64) % 5,
                )
            })
            .collect();
        for policy in [
            PolicyKind::Fifo,
            PolicyKind::FastLane,
            PolicyKind::Lrtp,
            PolicyKind::Rand,
            PolicyKind::Srtf,
            PolicyKind::Youngest,
            PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
            PolicyKind::PSrtf,
            PolicyKind::FitGppPr { s: 4.0, p_max: Some(1) },
        ] {
            let run = |engine: SimEngine| {
                let mut cfg = SimConfig::new(ClusterSpec::tiny(2), policy);
                cfg.paranoid = true;
                cfg.engine = engine;
                Simulator::new(cfg).run(&wl(specs.clone()))
            };
            let eh = run(SimEngine::EventHorizon);
            let pm = run(SimEngine::PerMinute);
            assert_eq!(eh.makespan, pm.makespan, "{policy:?} makespan");
            assert_eq!(eh.records, pm.records, "{policy:?} records");
            assert_eq!(
                eh.sched_stats.ticks, pm.sched_stats.ticks,
                "{policy:?} simulated minutes"
            );
            assert_eq!(pm.sched_stats.fast_forwards, 0);
        }
    }

    #[test]
    fn event_horizon_actually_fast_forwards() {
        // A lone long job leaves the cluster quiescent: the event-horizon
        // engine must cover almost the whole run in bulk burns.
        let mut cfg = SimConfig::new(ClusterSpec::tiny(1), PolicyKind::Fifo);
        cfg.engine = SimEngine::EventHorizon;
        let res = Simulator::new(cfg).run(&wl(vec![JobSpec::new(
            0, JobClass::Be, rv(4.0, 32.0, 1.0), 0, 5000, 0,
        )]));
        assert_eq!(res.makespan, 5001);
        assert!(res.sched_stats.fast_forwards >= 1);
        assert!(
            res.sched_stats.fast_forwarded_ticks >= 4999,
            "bulk-burned {} of {} minutes",
            res.sched_stats.fast_forwarded_ticks,
            res.sched_stats.ticks
        );
    }

    #[test]
    fn engines_agree_with_tail_cutoff_and_max_ticks() {
        let specs = vec![
            JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 1000, 0),
            JobSpec::new(1, JobClass::Be, rv(32.0, 256.0, 8.0), 3, 1000, 0),
        ];
        for (drain, tail, max) in [(false, 7, 10_000_000), (true, 0, 40), (false, 0, 2)] {
            let run = |engine: SimEngine| {
                let mut cfg = SimConfig::new(ClusterSpec::tiny(1), PolicyKind::Fifo);
                cfg.drain = drain;
                cfg.tail_ticks = tail;
                cfg.max_ticks = max;
                cfg.engine = engine;
                Simulator::new(cfg).run(&wl(specs.clone()))
            };
            let eh = run(SimEngine::EventHorizon);
            let pm = run(SimEngine::PerMinute);
            assert_eq!(eh.makespan, pm.makespan, "drain={drain} tail={tail} max={max}");
            assert_eq!(eh.records, pm.records);
            assert_eq!(eh.sched_stats.ticks, pm.sched_stats.ticks);
        }
    }

    #[test]
    fn streaming_sink_and_live_set_counters() {
        let mut cfg = SimConfig::new(ClusterSpec::tiny(2), PolicyKind::Fifo);
        cfg.paranoid = true;
        let specs: Vec<JobSpec> = (0..20)
            .map(|i| {
                JobSpec::new(i, if i % 3 == 0 { JobClass::Te } else { JobClass::Be },
                    rv(8.0, 64.0, 2.0), (i as u64) * 3, 7, 1)
            })
            .collect();
        let res = Simulator::new(cfg).run(&wl(specs));
        assert_eq!(res.metrics.jobs_seen, 20);
        assert_eq!(res.metrics.completed, 20);
        assert_eq!(res.metrics.unfinished, 0);
        // Arrivals are spread out: the live set must stay well below the
        // total job count.
        assert!(res.peak_live >= 1 && res.peak_live < 20, "peak {}", res.peak_live);
        // Sink-backed percentiles agree with the exact ones within the
        // sketch's error bound.
        let exact = res.slowdown_report();
        let sketch = res.metrics.slowdown_report();
        assert!((exact.be.p50 - sketch.be.p50).abs() / exact.be.p50 < 0.01);
    }

    #[test]
    fn record_jobs_off_reports_from_the_sink() {
        let specs: Vec<JobSpec> = (0..60)
            .map(|i| {
                JobSpec::new(i, if i % 4 == 0 { JobClass::Te } else { JobClass::Be },
                    rv(4.0 + (i % 3) as f64 * 8.0, 32.0, (i % 2) as f64 + 1.0),
                    (i as u64) / 3, 5 + (i as u64 % 13), (i as u64) % 4)
            })
            .collect();
        let mk = |record_jobs: bool| {
            let mut cfg = SimConfig::new(ClusterSpec::tiny(2), PolicyKind::FitGpp { s: 4.0, p_max: Some(1) });
            cfg.record_jobs = record_jobs;
            Simulator::new(cfg).run(&wl(specs.clone()))
        };
        let exact = mk(true);
        let streamed = mk(false);
        assert!(streamed.records.is_empty(), "no records kept");
        assert_eq!(streamed.metrics, exact.metrics, "sink is identical either way");
        assert_eq!(streamed.makespan, exact.makespan);
        let e = exact.slowdown_report();
        let s = streamed.slowdown_report();
        // At this small n the sketch's rank rounding (nearest sample vs
        // linear interpolation) dominates; the large-sample 1% bound is
        // asserted in rust/tests/streaming_equivalence.rs.
        for (a, b) in [(e.be.p50, s.be.p50), (e.te.p50, s.te.p50)] {
            assert!((a - b).abs() / a < 0.05, "exact {a} vs sketch {b}");
        }
        // Preemption stats are exact counters in both modes.
        assert_eq!(
            format!("{:?}", exact.preemption_report()),
            format!("{:?}", streamed.preemption_report())
        );
    }

    #[test]
    fn lookahead_window_does_not_change_results() {
        let specs: Vec<JobSpec> = (0..40)
            .map(|i| {
                JobSpec::new(i, if i % 4 == 0 { JobClass::Te } else { JobClass::Be },
                    rv(4.0 + (i % 3) as f64 * 8.0, 32.0, (i % 2) as f64 + 1.0),
                    (i as u64) * 2, 5 + (i as u64 % 13), (i as u64) % 4)
            })
            .collect();
        let mk = |lookahead: Minutes, engine: SimEngine| {
            let mut cfg = SimConfig::new(ClusterSpec::tiny(2), PolicyKind::Rand);
            cfg.seed = 5;
            cfg.engine = engine;
            cfg.arrival_lookahead = lookahead;
            cfg.paranoid = true;
            Simulator::new(cfg).run(&wl(specs.clone()))
        };
        let base = mk(0, SimEngine::EventHorizon);
        for lookahead in [1, 16, 10_000] {
            for engine in [SimEngine::EventHorizon, SimEngine::PerMinute] {
                let other = mk(lookahead, engine);
                assert_eq!(base.records, other.records, "lookahead {lookahead} {engine:?}");
                assert_eq!(base.makespan, other.makespan);
            }
        }
        // A big window pulls everything up front: the live set degenerates
        // to the materialized one.
        assert!(mk(10_000, SimEngine::EventHorizon).peak_live >= base.peak_live);
    }

    #[test]
    fn json_dump_parses_back() {
        let cfg = SimConfig::new(ClusterSpec::tiny(1), PolicyKind::Fifo);
        let res = Simulator::new(cfg).run(&wl(vec![JobSpec::new(
            0, JobClass::Te, rv(1.0, 1.0, 0.0), 0, 5, 0,
        )]));
        let j = res.to_json();
        let parsed = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(parsed.get("policy").as_str(), Some("FIFO"));
        assert_eq!(parsed.get("unfinished").as_u64(), Some(0));
        assert_eq!(parsed.get("cancelled").get("te").as_u64(), Some(0));
    }

    #[test]
    fn empty_scenario_changes_nothing() {
        // Attaching an empty script must leave every record and counter
        // byte-identical to a scenario-free run (the acceptance pin; the
        // full 7-policy × 2-engine sweep lives in
        // rust/tests/streaming_equivalence.rs).
        let specs: Vec<JobSpec> = (0..30)
            .map(|i| {
                JobSpec::new(i, if i % 3 == 0 { JobClass::Te } else { JobClass::Be },
                    rv(6.0 + (i % 4) as f64 * 8.0, 48.0, (i % 3) as f64),
                    (i as u64) / 2, 4 + (i as u64 % 11), (i as u64) % 4)
            })
            .collect();
        let mk = |scenario: Option<crate::sim::scenario::ScenarioScript>| {
            let mut cfg = SimConfig::new(
                ClusterSpec::tiny(2),
                PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
            );
            cfg.paranoid = true;
            cfg.scenario = scenario;
            Simulator::new(cfg).run(&wl(specs.clone()))
        };
        let plain = mk(None);
        let scripted = mk(Some(crate::sim::scenario::ScenarioScript::new()));
        assert_eq!(plain.records, scripted.records);
        assert_eq!(plain.metrics, scripted.metrics);
        assert_eq!(plain.makespan, scripted.makespan);
        assert_eq!(plain.sched_stats.ticks, scripted.sched_stats.ticks);
    }

    #[test]
    fn cancelled_jobs_are_recorded_but_not_pooled() {
        use crate::sched::control::SchedulerCommand;
        // One hog, one blocked job; cancel the hog at minute 3.
        let specs = vec![
            JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 1000, 0),
            JobSpec::new(1, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 5, 0),
        ];
        let mut cfg = SimConfig::new(ClusterSpec::tiny(1), PolicyKind::Fifo);
        cfg.paranoid = true;
        cfg.scenario = Some(
            crate::sim::scenario::ScenarioScript::new()
                .at(3, SchedulerCommand::Cancel { job: JobId(0) }),
        );
        let res = Simulator::new(cfg).run(&wl(specs));
        assert_eq!(res.cancelled(), (0, 1));
        assert_eq!(res.unfinished, 0, "cancelled is not unfinished");
        assert_eq!(res.records.len(), 2, "cancelled jobs keep a record");
        let hog = &res.records[0];
        assert!(hog.cancelled && hog.finished_at.is_none());
        // Job 1 got the freed seat at minute 3 and finished.
        assert_eq!(res.records[1].first_start, Some(3));
        assert_eq!(res.records[1].finished_at, Some(8));
        // Pooled stats ignore the cancelled hog entirely.
        assert_eq!(res.metrics.jobs_seen, 1);
        assert_eq!(res.slowdowns(JobClass::Be).len(), 1);
        assert_eq!(res.preempted_fraction(), 0.0);
    }

    #[test]
    fn session_snapshot_restore_is_byte_identical() {
        use crate::sched::control::SchedulerCommand;
        let specs: Vec<JobSpec> = (0..40)
            .map(|i| {
                JobSpec::new(i, if i % 4 == 0 { JobClass::Te } else { JobClass::Be },
                    rv(4.0 + (i % 3) as f64 * 8.0, 32.0, (i % 2) as f64 + 1.0),
                    (i as u64) * 2, 5 + (i as u64 % 13), (i as u64) % 4)
            })
            .collect();
        let mk_cfg = || {
            let mut cfg = SimConfig::new(
                ClusterSpec::tiny(2),
                PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
            );
            cfg.paranoid = true;
            cfg.seed = 7;
            cfg.scenario = Some(
                crate::sim::scenario::ScenarioScript::new()
                    .with_te_patience(4)
                    .at(10, SchedulerCommand::NodeDown { node: crate::cluster::NodeId(0) })
                    .at(30, SchedulerCommand::NodeUp { node: crate::cluster::NodeId(0) })
                    .at(15, SchedulerCommand::Cancel { job: JobId(7) }),
            );
            cfg
        };
        let baseline = {
            let workload = wl(specs.clone());
            let mut src = WorkloadSource::new(&workload);
            let mut sess = SimSession::new(mk_cfg(), Vec::new());
            sess.run_to_completion(&mut src);
            sess.finish(&mut src)
        };
        for cut in [0u64, 5, 12, 33] {
            let workload = wl(specs.clone());
            let mut src = WorkloadSource::new(&workload);
            let mut sess = SimSession::new(mk_cfg(), Vec::new());
            sess.run_until(&mut src, cut);
            let mut w = BinWriter::new();
            sess.snapshot_bin(&mut w);
            drop(sess); // the "kill"
            let bytes = w.into_bytes();

            let workload = wl(specs.clone());
            let mut src = WorkloadSource::new(&workload);
            let mut r = BinReader::new(&bytes);
            let mut back =
                SimSession::restore_bin(mk_cfg(), &mut r, Vec::new(), &mut src).unwrap();
            r.expect_end().unwrap();
            back.run_to_completion(&mut src);
            let res = back.finish(&mut src);
            assert_eq!(res.records, baseline.records, "cut {cut}");
            assert_eq!(res.metrics, baseline.metrics, "cut {cut}");
            assert_eq!(res.makespan, baseline.makespan, "cut {cut}");
            assert_eq!(res.unfinished, baseline.unfinished, "cut {cut}");
            assert_eq!(res.peak_live, baseline.peak_live, "cut {cut}");
            assert_eq!(
                format!("{:?}", res.sched_stats),
                format!("{:?}", baseline.sched_stats),
                "cut {cut}"
            );
        }
    }

    #[test]
    fn restore_rejects_a_different_configuration() {
        let specs = vec![JobSpec::new(0, JobClass::Be, rv(1.0, 1.0, 0.0), 0, 50, 0)];
        let workload = wl(specs);
        let mut src = WorkloadSource::new(&workload);
        let cfg = SimConfig::new(ClusterSpec::tiny(1), PolicyKind::Fifo);
        let mut sess = SimSession::new(cfg.clone(), Vec::new());
        sess.run_until(&mut src, 3);
        let mut w = BinWriter::new();
        sess.snapshot_bin(&mut w);
        let bytes = w.into_bytes();
        let mut other = cfg;
        other.seed = 1234;
        let mut src2 = WorkloadSource::new(&workload);
        let mut r = BinReader::new(&bytes);
        let err = SimSession::restore_bin(other, &mut r, Vec::new(), &mut src2)
            .err()
            .expect("config mismatch must be rejected");
        assert!(err.to_string().contains("different configuration"), "{err}");
    }

    #[test]
    fn scenario_runs_agree_across_engines_and_lookahead() {
        use crate::sched::control::SchedulerCommand;
        let specs: Vec<JobSpec> = (0..40)
            .map(|i| {
                JobSpec::new(i, if i % 4 == 0 { JobClass::Te } else { JobClass::Be },
                    rv(4.0 + (i % 3) as f64 * 8.0, 32.0, (i % 2) as f64 + 1.0),
                    (i as u64) * 2, 5 + (i as u64 % 13), (i as u64) % 4)
            })
            .collect();
        let scenario = crate::sim::scenario::ScenarioScript::new()
            .with_te_patience(3)
            .at(10, SchedulerCommand::NodeDown { node: crate::cluster::NodeId(0) })
            .at(40, SchedulerCommand::NodeUp { node: crate::cluster::NodeId(0) })
            .at(20, SchedulerCommand::Drain { node: crate::cluster::NodeId(1) })
            .at(55, SchedulerCommand::NodeUp { node: crate::cluster::NodeId(1) })
            .at(15, SchedulerCommand::Cancel { job: JobId(7) })
            // Pre-arrival cancel: job 35 submits at minute 70; the cancel
            // is issued at 5 and must defer identically whatever the
            // lookahead window staged.
            .at(5, SchedulerCommand::Cancel { job: JobId(35) });
        let mk = |engine: SimEngine, lookahead: Minutes| {
            let policy = PolicyKind::FitGpp { s: 4.0, p_max: Some(1) };
            let mut cfg = SimConfig::new(ClusterSpec::tiny(2), policy);
            cfg.paranoid = true;
            cfg.engine = engine;
            cfg.arrival_lookahead = lookahead;
            cfg.scenario = Some(scenario.clone());
            Simulator::new(cfg).run(&wl(specs.clone()))
        };
        let base = mk(SimEngine::PerMinute, 0);
        assert!(base.cancelled().0 + base.cancelled().1 >= 2, "{:?}", base.cancelled());
        assert_eq!(base.unfinished, 0, "scenario run still drains");
        for engine in [SimEngine::PerMinute, SimEngine::EventHorizon] {
            for lookahead in [0u64, 1, 32, 1 << 20] {
                let other = mk(engine, lookahead);
                assert_eq!(base.records, other.records, "{engine:?}/{lookahead}");
                assert_eq!(base.metrics, other.metrics, "{engine:?}/{lookahead}");
                assert_eq!(base.makespan, other.makespan, "{engine:?}/{lookahead}");
            }
        }
    }
}
