//! Tiny benchmark harness (criterion is not available offline).
//!
//! Provides warmup + repeated timing with median/min/max reporting, and a
//! `BenchReport` that accumulates named measurements and renders them as a
//! table. Every `rust/benches/*.rs` target (`harness = false`) uses this.

use crate::util::table::Table;
use std::time::{Duration, Instant};

/// One timed measurement.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    /// Median of the measured runs.
    pub median: Duration,
    /// Fastest run.
    pub min: Duration,
    /// Slowest run.
    pub max: Duration,
    /// Measured iterations.
    pub iters: usize,
}

impl Measurement {
    /// Median in milliseconds.
    pub fn median_ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }
}

/// Time `f` with `warmup` unmeasured runs followed by `iters` measured
/// runs. Returns median/min/max. `f` should return something observable to
/// keep the optimizer honest; its return value is black-boxed.
pub fn time_fn<T, F: FnMut() -> T>(warmup: usize, iters: usize, mut f: F) -> Measurement {
    assert!(iters > 0);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    Measurement {
        median: samples[samples.len() / 2],
        min: samples[0],
        max: samples[samples.len() - 1],
        iters,
    }
}

/// Prevent the optimizer from discarding a value (stable-rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Named measurement collection + table rendering.
#[derive(Debug, Default)]
pub struct BenchReport {
    rows: Vec<(String, Measurement)>,
}

impl BenchReport {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, name: &str, m: Measurement) {
        println!(
            "  {name}: median {:.2} ms (min {:.2}, max {:.2}, n={})",
            m.median_ms(),
            m.min.as_secs_f64() * 1e3,
            m.max.as_secs_f64() * 1e3,
            m.iters
        );
        self.rows.push((name.to_string(), m));
    }

    /// Run-and-record convenience.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, warmup: usize, iters: usize, f: F) {
        let m = time_fn(warmup, iters, f);
        self.record(name, m);
    }

    pub fn table(&self, title: &str) -> Table {
        let mut t = Table::new(title, &["benchmark", "median (ms)", "min (ms)", "max (ms)", "iters"]);
        for (name, m) in &self.rows {
            t.row(vec![
                name.clone(),
                format!("{:.3}", m.median_ms()),
                format!("{:.3}", m.min.as_secs_f64() * 1e3),
                format!("{:.3}", m.max.as_secs_f64() * 1e3),
                m.iters.to_string(),
            ]);
        }
        t
    }

    pub fn get(&self, name: &str) -> Option<Measurement> {
        self.rows.iter().find(|(n, _)| n == name).map(|(_, m)| *m)
    }
}

/// Standard env-var scaling for bench workload sizes: benches default to a
/// fast size but honour `FITGPP_JOBS` (etc.) for full-paper-scale runs.
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures_something() {
        let m = time_fn(1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(m.min <= m.median && m.median <= m.max);
        assert_eq!(m.iters, 5);
    }

    #[test]
    fn report_accumulates_and_renders() {
        let mut r = BenchReport::new();
        r.bench("noop", 0, 3, || 1 + 1);
        assert!(r.get("noop").is_some());
        let t = r.table("bench");
        assert!(t.to_text().contains("noop"));
    }

    #[test]
    fn env_usize_default() {
        assert_eq!(env_usize("FITGPP_NONEXISTENT_VAR_XYZ", 7), 7);
    }
}
