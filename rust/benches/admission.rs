//! Admission-layer dispatch bench: stream a million-job institution trace
//! with 1000 tenants under the `WeightedFair` discipline and compare
//! jobs/sec against the `Fifo` baseline, writing `BENCH_admission.json`.
//!
//! The queue discipline sits on the hot admission path (one round per
//! scheduling tick), so this bench keeps its dispatch cost visible: the
//! baseline pays the same tenant-identity and per-tenant-metrics costs
//! (both runs stream the identical tenant-tagged trace), isolating the
//! delta to the discipline itself — trait dispatch, per-tenant sub-queues,
//! and round-robin bookkeeping.
//!
//! Scale knobs: `FITGPP_ADMISSION_JOBS` (default 1_000_000),
//! `FITGPP_ADMISSION_TENANTS` (default 1000), `FITGPP_SEED`.

#[path = "common/mod.rs"]
mod common;

use fitgpp::benchkit::env_usize;
use fitgpp::cluster::ClusterSpec;
use fitgpp::sched::admission::DisciplineKind;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sim::{SimConfig, SimResult, Simulator};
use fitgpp::util::json::Json;
use fitgpp::workload::source::TenantAssigner;
use fitgpp::workload::trace::InstitutionSource;
use std::time::Instant;

fn run(discipline: DisciplineKind, jobs: usize, tenants: u32, seed: u64) -> (SimResult, f64) {
    let policy = PolicyKind::FitGpp { s: 4.0, p_max: Some(1) };
    let mut cfg = SimConfig::new(ClusterSpec::pfn(), policy);
    cfg.seed = seed;
    cfg.record_jobs = false; // streaming mode: the discipline is the variable
    cfg.discipline = discipline;
    let mut source =
        InstitutionSource::new(seed, jobs).with_tenants(TenantAssigner::round_robin(tenants));
    let t0 = Instant::now();
    let res = Simulator::new(cfg).run_source(&mut source);
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(res.metrics.jobs_seen, jobs as u64, "every job observed");
    assert_eq!(res.unfinished, 0, "drain mode completes everything");
    (res, wall)
}

fn main() {
    let jobs = env_usize("FITGPP_ADMISSION_JOBS", 1_000_000);
    let tenants = env_usize("FITGPP_ADMISSION_TENANTS", 1000) as u32;
    let seed = env_usize("FITGPP_SEED", 9) as u64;
    println!("admission: streaming {jobs} jobs across {tenants} tenants, fifo vs weighted_fair");

    let (fifo_res, fifo_wall) = run(DisciplineKind::Fifo, jobs, tenants, seed);
    let (wf_res, wf_wall) = run(DisciplineKind::WeightedFair, jobs, tenants, seed);

    assert_eq!(fifo_res.metrics.tenants.len(), tenants as usize);
    assert_eq!(wf_res.metrics.tenants.len(), tenants as usize);

    let fifo_rate = jobs as f64 / fifo_wall.max(1e-9);
    let wf_rate = jobs as f64 / wf_wall.max(1e-9);
    let mut out = String::new();
    out.push_str(&format!(
        "fifo:          {jobs} jobs in {fifo_wall:.1}s = {fifo_rate:.0} jobs/sec (makespan {} min)\n",
        fifo_res.makespan
    ));
    out.push_str(&format!(
        "weighted_fair: {jobs} jobs in {wf_wall:.1}s = {wf_rate:.0} jobs/sec (makespan {} min)\n",
        wf_res.makespan
    ));
    out.push_str(&format!(
        "discipline dispatch cost: {:.1}% throughput vs the fifo baseline\n",
        100.0 * wf_rate / fifo_rate.max(1e-9)
    ));
    common::save_results("admission", &out);

    common::save_results_json(
        "admission",
        &Json::obj(vec![
            ("jobs", Json::num(jobs as f64)),
            ("tenants", Json::num(tenants as f64)),
            ("seed", Json::num(seed as f64)),
            (
                "fifo",
                Json::obj(vec![
                    ("wall_sec", Json::num(fifo_wall)),
                    ("jobs_per_sec", Json::num(fifo_rate)),
                    ("makespan", Json::num(fifo_res.makespan as f64)),
                    ("peak_live", Json::num(fifo_res.peak_live as f64)),
                ]),
            ),
            (
                "weighted_fair",
                Json::obj(vec![
                    ("wall_sec", Json::num(wf_wall)),
                    ("jobs_per_sec", Json::num(wf_rate)),
                    ("makespan", Json::num(wf_res.makespan as f64)),
                    ("peak_live", Json::num(wf_res.peak_live as f64)),
                    ("admission_skips", Json::num(wf_res.sched_stats.admission_skips as f64)),
                ]),
            ),
            ("throughput_ratio", Json::num(wf_rate / fifo_rate.max(1e-9))),
        ]),
    );
}
