//! Fig. 4: FitGpp slowdown percentiles as a function of `s` (the weight
//! of grace-period length vs demand size in Eq. 3). Paper shape: TE
//! slowdown falls with s and saturates between s = 4 and s = 8; BE
//! slowdown is essentially independent of s.

#[path = "common/mod.rs"]
mod common;

use fitgpp::job::JobClass;
use fitgpp::metrics::Percentiles;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::util::table::Table;

fn main() {
    let jobs = common::jobs_default();
    let seeds = common::seeds_default();
    println!("fig4_sensitivity_s: {jobs} jobs x {seeds} seeds (P = 1)");

    let mut t = Table::new(
        "Fig. 4: FitGpp slowdown vs s",
        &["s", "TE p50", "TE p95", "TE p99", "BE p50", "BE p95", "BE p99"],
    );
    for s_param in [0.0, 0.5, 1.0, 2.0, 4.0, 8.0] {
        let policy = PolicyKind::FitGpp { s: s_param, p_max: Some(1) };
        let te = Percentiles::of(&common::pooled_slowdowns(policy, seeds, jobs, JobClass::Te));
        let be = Percentiles::of(&common::pooled_slowdowns(policy, seeds, jobs, JobClass::Be));
        t.row(vec![
            format!("{s_param}"),
            format!("{:.3}", te.p50),
            format!("{:.3}", te.p95),
            format!("{:.3}", te.p99),
            format!("{:.2}", be.p50),
            format!("{:.2}", be.p95),
            format!("{:.2}", be.p99),
        ]);
    }
    common::save_results("fig4_sensitivity_s", &t.to_text());
}
