//! Fig. 4: FitGpp slowdown percentiles as a function of `s` (the weight
//! of grace-period length vs demand size in Eq. 3). Paper shape: TE
//! slowdown falls with s and saturates between s = 4 and s = 8; BE
//! slowdown is essentially independent of s.
//!
//! Driven by the parallel sweep harness: the whole s × seed grid runs as
//! one work-stealing sweep, and workloads are generated once per seed and
//! shared across the six s points.

#[path = "common/mod.rs"]
mod common;

use fitgpp::job::JobClass;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sweep::SweepSpec;
use fitgpp::util::table::Table;

fn main() {
    let jobs = common::jobs_default();
    let seeds = common::seeds_default();
    let s_grid = [0.0, 0.5, 1.0, 2.0, 4.0, 8.0];
    let spec = SweepSpec::new(common::cluster(), Vec::new())
        .fitgpp_s_grid(&s_grid, Some(1))
        .with_num_jobs(jobs)
        .with_seeds((0..seeds).map(|i| 100 + i as u64).collect());
    println!(
        "fig4_sensitivity_s: {jobs} jobs x {seeds} seeds (P = 1), {} threads",
        spec.threads_effective()
    );
    let res = spec.run();

    let mut t = Table::new(
        "Fig. 4: FitGpp slowdown vs s",
        &["s", "TE p50", "TE p95", "TE p99", "BE p50", "BE p95", "BE p99"],
    );
    for &s_param in &s_grid {
        let policy = PolicyKind::FitGpp { s: s_param, p_max: Some(1) };
        let te = res.pooled_percentiles(policy, JobClass::Te);
        let be = res.pooled_percentiles(policy, JobClass::Be);
        t.row(vec![
            format!("{s_param}"),
            format!("{:.3}", te.p50),
            format!("{:.3}", te.p95),
            format!("{:.3}", te.p99),
            format!("{:.2}", be.p50),
            format!("{:.2}", be.p95),
            format!("{:.2}", be.p99),
        ]);
    }
    common::report_sweep(&res);
    common::save_results("fig4_sensitivity_s", &t.to_text());
}
