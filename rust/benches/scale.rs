//! Streaming scale bench: replay a million-job institution trace through
//! the streaming simulator and record throughput (jobs/sec, simulated
//! minutes/sec) and the peak resident live set to `BENCH_scale.json`.
//!
//! This is the headline number for the streaming layer: total jobs are
//! *not* materialized anywhere — the trace is generated on the fly by
//! [`InstitutionSource`] and every completed job retires into the
//! mergeable metrics sink — so the run's resident job state is bounded by
//! the live set (asserted here via the high-water counter, not RSS).
//!
//! Scale knobs: `FITGPP_SCALE_JOBS` (default 1_000_000), `FITGPP_SEED`.

#[path = "common/mod.rs"]
mod common;

use fitgpp::benchkit::env_usize;
use fitgpp::cluster::ClusterSpec;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sim::{SimConfig, Simulator};
use fitgpp::util::json::Json;
use fitgpp::workload::trace::InstitutionSource;
use std::time::Instant;

fn main() {
    let jobs = env_usize("FITGPP_SCALE_JOBS", 1_000_000);
    let seed = env_usize("FITGPP_SEED", 9) as u64;
    let policy = PolicyKind::FitGpp { s: 4.0, p_max: Some(1) };
    println!("scale: streaming {jobs} institution-trace jobs under {}", policy.name());

    let mut cfg = SimConfig::new(ClusterSpec::pfn(), policy);
    cfg.seed = seed;
    cfg.record_jobs = false; // the point: no O(total-jobs) record vector
    let mut source = InstitutionSource::new(seed, jobs);

    let t0 = Instant::now();
    let res = Simulator::new(cfg).run_source(&mut source);
    let wall = t0.elapsed().as_secs_f64();

    assert_eq!(res.metrics.jobs_seen, jobs as u64, "every job must be observed");
    assert_eq!(res.unfinished, 0, "drain mode completes everything");
    assert!(
        res.peak_live < jobs,
        "peak live set {} must be bounded by the live set, not total jobs",
        res.peak_live
    );

    let sd = res.slowdown_report();
    let jobs_per_sec = jobs as f64 / wall.max(1e-9);
    let sim_minutes_per_sec = res.makespan as f64 / wall.max(1e-9);
    let mut out = String::new();
    out.push_str(&format!(
        "streamed {jobs} jobs in {wall:.1}s: {jobs_per_sec:.0} jobs/sec, {sim_minutes_per_sec:.0} simulated min/sec\n"
    ));
    out.push_str(&format!(
        "peak live set: {} jobs ({:.3}% of total); makespan {} min ({:.1} simulated days)\n",
        res.peak_live,
        100.0 * res.peak_live as f64 / jobs as f64,
        res.makespan,
        res.makespan as f64 / 1440.0
    ));
    out.push_str(&format!(
        "sketch-backed slowdowns: TE p50 {:.2} p95 {:.2} p99 {:.2} | BE p50 {:.2} p95 {:.2} p99 {:.2}\n",
        sd.te.p50, sd.te.p95, sd.te.p99, sd.be.p50, sd.be.p95, sd.be.p99
    ));
    common::save_results("scale", &out);

    common::save_results_json(
        "scale",
        &Json::obj(vec![
            ("jobs", Json::num(jobs as f64)),
            ("seed", Json::num(seed as f64)),
            ("policy", Json::str(&policy.name())),
            ("wall_sec", Json::num(wall)),
            ("jobs_per_sec", Json::num(jobs_per_sec)),
            ("sim_minutes_per_sec", Json::num(sim_minutes_per_sec)),
            ("peak_live", Json::num(res.peak_live as f64)),
            ("makespan", Json::num(res.makespan as f64)),
            ("unfinished", Json::num(res.unfinished as f64)),
            (
                "slowdown",
                Json::obj(vec![("te", sd.te.to_json()), ("be", sd.be.to_json())]),
            ),
        ]),
    );
}
