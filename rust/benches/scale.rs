//! Streaming scale bench: replay a million-job institution trace through
//! the streaming simulator and record throughput (jobs/sec, simulated
//! minutes/sec) and the peak resident live set to `BENCH_scale.json`.
//!
//! This is the headline number for the streaming layer: total jobs are
//! *not* materialized anywhere — the trace is generated on the fly by
//! [`InstitutionSource`] and every completed job retires into the
//! mergeable metrics sink — so the run's resident job state is bounded by
//! the live set (asserted here via the high-water counter, not RSS).
//!
//! Scale knobs: `FITGPP_SCALE_JOBS` (default 1_000_000), `FITGPP_SEED`,
//! and `FITGPP_CELLS` (default 1 — the plain single-scheduler replay the
//! perf gate compares; `K > 1` shards the cluster into `K` independent
//! cells via [`fitgpp::sim::cells`], each streaming its own trace slice
//! on its own core; cell throughputs are not comparable across different
//! `K`, so the cell count is recorded in the JSON).

#[path = "common/mod.rs"]
mod common;

use fitgpp::benchkit::env_usize;
use fitgpp::cluster::ClusterSpec;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sim::cells::{merge_results, split_cluster};
use fitgpp::sim::{SimConfig, Simulator};
use fitgpp::sweep::parallel_map;
use fitgpp::util::json::Json;
use fitgpp::workload::trace::InstitutionSource;
use std::time::Instant;

fn main() {
    let jobs = env_usize("FITGPP_SCALE_JOBS", 1_000_000);
    let seed = env_usize("FITGPP_SEED", 9) as u64;
    let cells = env_usize("FITGPP_CELLS", 1).max(1);
    let policy = PolicyKind::FitGpp { s: 4.0, p_max: Some(1) };
    println!(
        "scale: streaming {jobs} institution-trace jobs under {} ({cells} cell{})",
        policy.name(),
        if cells == 1 { "" } else { "s" }
    );

    let mut cfg = SimConfig::new(ClusterSpec::pfn(), policy);
    cfg.seed = seed;
    cfg.record_jobs = false; // the point: no O(total-jobs) record vector

    let t0 = Instant::now();
    let res = if cells == 1 {
        let mut source = InstitutionSource::new(seed, jobs);
        Simulator::new(cfg).run_source(&mut source)
    } else {
        // Sharded replay: K node slices, each streaming its own share of
        // the trace (seeds decorrelated per cell) on its own worker.
        let slices = split_cluster(&cfg.cluster, cells);
        let k = slices.len();
        let base = jobs / k;
        let rem = jobs % k;
        let cell_cfgs: Vec<(SimConfig, usize, u64)> = slices
            .into_iter()
            .enumerate()
            .map(|(i, cluster)| {
                let mut c = cfg.clone();
                c.cluster = cluster;
                c.seed = seed.wrapping_add(i as u64);
                (c, base + usize::from(i < rem), seed.wrapping_add(i as u64))
            })
            .collect();
        let parts = parallel_map(&cell_cfgs, k, |_, (c, n, s)| {
            let mut source = InstitutionSource::new(*s, *n);
            Simulator::new(c.clone()).run_source(&mut source)
        });
        merge_results(parts)
    };
    let wall = t0.elapsed().as_secs_f64();

    assert_eq!(res.metrics.jobs_seen, jobs as u64, "every job must be observed");
    assert_eq!(res.unfinished, 0, "drain mode completes everything");
    assert!(
        res.peak_live < jobs,
        "peak live set {} must be bounded by the live set, not total jobs",
        res.peak_live
    );

    let sd = res.slowdown_report();
    let jobs_per_sec = jobs as f64 / wall.max(1e-9);
    let sim_minutes_per_sec = res.makespan as f64 / wall.max(1e-9);
    let mut out = String::new();
    out.push_str(&format!(
        "streamed {jobs} jobs in {wall:.1}s: {jobs_per_sec:.0} jobs/sec, {sim_minutes_per_sec:.0} simulated min/sec\n"
    ));
    out.push_str(&format!(
        "peak live set: {} jobs ({:.3}% of total); makespan {} min ({:.1} simulated days)\n",
        res.peak_live,
        100.0 * res.peak_live as f64 / jobs as f64,
        res.makespan,
        res.makespan as f64 / 1440.0
    ));
    out.push_str(&format!(
        "sketch-backed slowdowns: TE p50 {:.2} p95 {:.2} p99 {:.2} | BE p50 {:.2} p95 {:.2} p99 {:.2}\n",
        sd.te.p50, sd.te.p95, sd.te.p99, sd.be.p50, sd.be.p95, sd.be.p99
    ));
    common::save_results("scale", &out);

    common::save_results_json(
        "scale",
        &Json::obj(vec![
            ("jobs", Json::num(jobs as f64)),
            ("seed", Json::num(seed as f64)),
            ("cells", Json::num(cells as f64)),
            ("policy", Json::str(&policy.name())),
            ("wall_sec", Json::num(wall)),
            ("jobs_per_sec", Json::num(jobs_per_sec)),
            ("sim_minutes_per_sec", Json::num(sim_minutes_per_sec)),
            ("peak_live", Json::num(res.peak_live as f64)),
            ("makespan", Json::num(res.makespan as f64)),
            ("unfinished", Json::num(res.unfinished as f64)),
            (
                "slowdown",
                Json::obj(vec![("te", sd.te.to_json()), ("be", sd.be.to_json())]),
            ),
        ]),
    );
}
