//! Fig. 7: 95th-percentile slowdown vs the grace-period length scale.
//! The "1.0" column samples GPs from the §4.2 distribution; "k" scales
//! mean, σ, and truncation by k. Paper shape: TE slowdown grows with GP
//! length for every policy; a larger s counters it (FitGpp s=8 beats s=4
//! at scale 8); FitGpp keeps BE slowdown flat where LRTP/RAND degrade.
//!
//! Driven by the parallel sweep harness: the GP-scale axis is a grid
//! dimension, one workload per scale, all cells work-stealing in parallel.

#[path = "common/mod.rs"]
mod common;

use fitgpp::job::JobClass;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sweep::SweepSpec;
use fitgpp::util::table::Table;

fn main() {
    let jobs = common::jobs_default();
    let scales = vec![1.0, 2.0, 4.0, 8.0];
    let policies = [
        ("LRTP".to_string(), PolicyKind::Lrtp),
        ("RAND".to_string(), PolicyKind::Rand),
        ("FitGpp (s=4.0)".to_string(), PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }),
        ("FitGpp (s=8.0)".to_string(), PolicyKind::FitGpp { s: 8.0, p_max: Some(1) }),
    ];
    let spec = SweepSpec::new(
        common::cluster(),
        policies.iter().map(|(_, p)| *p).collect(),
    )
    .with_num_jobs(jobs)
    .with_seeds(vec![7])
    .with_gp_scales(scales.clone());
    println!(
        "fig7_gp_scale: {jobs} jobs per point, {} threads",
        spec.threads_effective()
    );
    let res = spec.run();

    let mut t = Table::new(
        "Fig. 7: p95 slowdown vs GP-length scale",
        &["GP scale", "policy", "TE p95", "BE p95"],
    );
    for &scale in &scales {
        for (name, policy) in &policies {
            let te = res.pooled_percentiles_where(
                |c| c.policy == *policy && c.gp_scale == scale,
                JobClass::Te,
            );
            let be = res.pooled_percentiles_where(
                |c| c.policy == *policy && c.gp_scale == scale,
                JobClass::Be,
            );
            t.row(vec![
                format!("{scale}"),
                name.clone(),
                format!("{:.2}", te.p95),
                format!("{:.2}", be.p95),
            ]);
        }
    }
    common::report_sweep(&res);
    common::save_results("fig7_gp_scale", &t.to_text());
}
