//! Fig. 7: 95th-percentile slowdown vs the grace-period length scale.
//! The "1.0" column samples GPs from the §4.2 distribution; "k" scales
//! mean, σ, and truncation by k. Paper shape: TE slowdown grows with GP
//! length for every policy; a larger s counters it (FitGpp s=8 beats s=4
//! at scale 8); FitGpp keeps BE slowdown flat where LRTP/RAND degrade.

#[path = "common/mod.rs"]
mod common;

use fitgpp::job::JobClass;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::stats::summary::percentile;
use fitgpp::util::table::Table;
use fitgpp::workload::synthetic::SyntheticWorkload;

fn main() {
    let jobs = common::jobs_default();
    println!("fig7_gp_scale: {jobs} jobs per point");

    let policies = [
        ("LRTP".to_string(), PolicyKind::Lrtp),
        ("RAND".to_string(), PolicyKind::Rand),
        ("FitGpp (s=4.0)".to_string(), PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }),
        ("FitGpp (s=8.0)".to_string(), PolicyKind::FitGpp { s: 8.0, p_max: Some(1) }),
    ];
    let mut t = Table::new(
        "Fig. 7: p95 slowdown vs GP-length scale",
        &["GP scale", "policy", "TE p95", "BE p95"],
    );
    for scale in [1.0, 2.0, 4.0, 8.0] {
        let wl = SyntheticWorkload::paper_section_4_2(7)
            .with_cluster(common::cluster())
            .with_num_jobs(jobs)
            .with_gp_scale(scale)
            .generate();
        for (name, policy) in &policies {
            let res = common::run_policy(&wl, *policy, 1);
            let te = res.slowdowns(JobClass::Te);
            let be = res.slowdowns(JobClass::Be);
            t.row(vec![
                format!("{scale}"),
                name.clone(),
                format!("{:.2}", percentile(&te, 95.0)),
                format!("{:.2}", percentile(&be, 95.0)),
            ]);
        }
    }
    common::save_results("fig7_gp_scale", &t.to_text());
}
