//! Scheduler hot-path micro-benchmarks (the §Perf L3 targets) plus design
//! ablations called out in DESIGN.md:
//!
//! * **per-op microbenches with allocation counts** — steady quiescent
//!   tick, admission round on a blocked queue, a full
//!   place→complete→retire event cycle, and a clock push/pop cycle, each
//!   reported as ns/op *and* allocs/op via a counting global allocator
//!   (this bench binary only). The allocation-free hot-path guarantee is
//!   machine-checked: `BENCH_hotpath.json` carries
//!   `steady_state_allocs_per_op`, which `scripts/perf_gate.sh` pins to 0.
//! * end-to-end simulation throughput (jobs/s) per policy
//! * FitGpp victim-scan latency at various running-job counts
//! * placement-search latency (first/best/worst fit ablation)
//! * percentile computation and synthetic-workload generation

#[path = "common/mod.rs"]
mod common;

use fitgpp::benchkit::{black_box, BenchReport};
use fitgpp::cluster::{Cluster, ClusterSpec, Placement};
use fitgpp::job::{Job, JobClass, JobId, JobSpec};
use fitgpp::job_table::JobTable;
use fitgpp::resources::ResourceVec;
use fitgpp::sched::policy::{fitgpp as fitgpp_policy, PlanScratch, PolicyCtx, PolicyKind};
use fitgpp::sched::{EventClock, SchedConfig, Scheduler, TickStats, VictimIndex};
use fitgpp::sim::{SimConfig, Simulator};
use fitgpp::stats::rng::Pcg64;
use fitgpp::stats::summary::percentiles;
use fitgpp::util::json::Json;
use fitgpp::workload::synthetic::SyntheticWorkload;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

// --- counting allocator (this bench binary only) ------------------------
//
// Counts every alloc/realloc so per-op measurements can report allocs/op
// exactly. Deallocations are free to happen (dropping a retired job must
// not count as "the hot path allocated").

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// One per-op measurement: wall time and heap allocations, both divided
/// by the iteration count. Warmup runs first (scratch buffers, heaps, and
/// hash maps reach their steady capacity there) and is excluded.
#[derive(Clone, Copy)]
struct OpStats {
    ns_per_op: f64,
    allocs_per_op: f64,
}

fn measure_op<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> OpStats {
    for _ in 0..warmup {
        f();
    }
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let dt = t0.elapsed();
    let allocs = ALLOCS.load(Ordering::Relaxed) - a0;
    OpStats {
        ns_per_op: dt.as_secs_f64() * 1e9 / iters as f64,
        allocs_per_op: allocs as f64 / iters as f64,
    }
}

fn rv(c: f64, r: f64, g: f64) -> ResourceVec {
    ResourceVec::new(c, r, g)
}

/// Build a cluster with `n_jobs` running BE jobs spread across 84 nodes.
fn packed_cluster(n_jobs: usize) -> (Cluster, JobTable) {
    let spec = ClusterSpec::pfn();
    let mut cluster = Cluster::new(&spec);
    let mut jobs = Vec::new();
    let mut rng = Pcg64::new(42);
    let mut placed = 0;
    while placed < n_jobs {
        let demand = rv(
            1.0 + rng.below(8) as f64,
            8.0 + rng.below(64) as f64,
            rng.below(3) as f64,
        );
        let Some(node) = cluster.find_node(&demand, Placement::FirstFit) else {
            break;
        };
        let s = JobSpec::new(placed as u32, JobClass::Be, demand, 0, 60, rng.below(20));
        let mut j = Job::new(s);
        j.start(node, 0);
        cluster.bind(JobId(placed as u32), demand, node);
        jobs.push(j);
        placed += 1;
    }
    (cluster, JobTable::from_jobs(jobs))
}

/// A scheduler with `running` long BE jobs placed at minute 0 and, when
/// `blocked > 0`, that many additional queued jobs too large to ever fit.
/// Returns the scheduler, table, reused tick stats, and the next minute.
fn steady_scheduler(
    policy: PolicyKind,
    running: u32,
    blocked: u32,
) -> (Scheduler, JobTable, TickStats, u64) {
    let spec = ClusterSpec::pfn();
    let mut sched = Scheduler::new(&spec, SchedConfig::new(policy));
    let mut jobs = JobTable::new();
    let mut arrivals = Vec::new();
    for i in 0..running {
        jobs.insert(Job::new(JobSpec::new(
            i,
            JobClass::Be,
            rv(2.0, 16.0, 0.0),
            0,
            100_000_000,
            0,
        )));
        arrivals.push(JobId(i));
    }
    for i in running..running + blocked {
        // Demands over any single node's capacity: queued forever.
        jobs.insert(Job::new(JobSpec::new(
            i,
            JobClass::Be,
            rv(1000.0, 1000.0, 1000.0),
            0,
            10,
            0,
        )));
        arrivals.push(JobId(i));
    }
    let mut out = TickStats::default();
    sched.tick_into(0, &mut jobs, &arrivals, &mut out);
    (sched, jobs, out, 1)
}

fn main() {
    let mut r = BenchReport::new();
    let mut ops: Vec<(&'static str, OpStats)> = Vec::new();

    // --- per-op microbenches (ns/op + allocs/op) ----------------------

    // Steady quiescent tick: running jobs, nothing due, empty queues.
    // The whole round is a heap peek plus empty admission scans.
    {
        let (mut sched, mut jobs, mut out, mut now) = steady_scheduler(PolicyKind::Fifo, 64, 0);
        let m = measure_op(1_000, 100_000, || {
            sched.tick_into(now, &mut jobs, &[], &mut out);
            now += 1;
        });
        ops.push(("steady_quiescent_tick", m));
    }

    // Admission round with a blocked 256-deep BE queue: every tick walks
    // the admission path against a queue nothing can unblock.
    {
        let (mut sched, mut jobs, mut out, mut now) = steady_scheduler(PolicyKind::Fifo, 64, 256);
        let m = measure_op(1_000, 50_000, || {
            sched.tick_into(now, &mut jobs, &[], &mut out);
            now += 1;
        });
        ops.push(("admission_round_blocked_256", m));
    }

    // Placement + event application: each op inserts a 1-minute job,
    // places it (arrival tick), completes it via the clock (next tick),
    // and retires it from the table — the full lifecycle the streamed
    // replay pays per job.
    {
        let (mut sched, mut jobs, mut out, mut now) = steady_scheduler(PolicyKind::Fifo, 8, 0);
        let warmup = 1_000u32;
        let iters = 100_000u32;
        // Pre-size the id → slot map so its one-time growth does not
        // pollute the measured window.
        let top = 8 + warmup + iters + 1;
        jobs.insert(Job::new(JobSpec::new(top, JobClass::Be, rv(1.0, 1.0, 0.0), 0, 1, 0)));
        jobs.remove(JobId(top));
        let mut next_id = 8u32;
        let m = measure_op(warmup as usize, iters as usize, || {
            let id = next_id;
            next_id += 1;
            jobs.insert(Job::new(JobSpec::new(
                id,
                JobClass::Be,
                rv(1.0, 8.0, 0.0),
                now,
                1,
                0,
            )));
            sched.tick_into(now, &mut jobs, &[JobId(id)], &mut out);
            sched.tick_into(now + 1, &mut jobs, &[], &mut out);
            jobs.remove(JobId(id));
            now += 2;
        });
        ops.push(("place_complete_retire_cycle", m));
    }

    // Clock push/pop cycle: one completion entry pushed and drained per
    // op through the same heap the scheduler uses.
    {
        let mut clock = EventClock::new();
        let mut jobs = JobTable::new();
        jobs.insert(Job::new(JobSpec::new(0, JobClass::Be, rv(1.0, 1.0, 0.0), 0, 10, 0)));
        let epoch = jobs.epoch_of(JobId(0)).unwrap();
        let mut due: Vec<u32> = Vec::new();
        let mut now = 0u64;
        let m = measure_op(1_000, 200_000, || {
            clock.push_completion(now, JobId(0), epoch);
            clock.take_due_into(now, &jobs, &mut due);
            black_box(due.len());
            now += 1;
        });
        ops.push(("clock_push_pop_cycle", m));
    }

    // Full plan path against a saturated cluster: every op runs one TE
    // admission that walks the whole FitGpp victim scan (all N candidates
    // p-capped, so Eq. 4 finds nothing) and the RAND fallback (whose
    // p-filtered pool is empty — `pick_index(0)` returns None without a
    // draw, so the op is deterministic and repeatable). This is the
    // O(candidates) planning cost the victim index bounds; the gate pins
    // its alloc rate to zero.
    for n in [256u32, 4096] {
        // 16 jobs of (2 cpu, 16 GB) pack one tiny node exactly: the TE
        // job below fits a node's *capacity* but never its free space.
        let spec = ClusterSpec::tiny((n / 16) as usize);
        let mut sched = Scheduler::new(
            &spec,
            SchedConfig::new(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }),
        );
        let mut jobs = JobTable::new();
        let mut arrivals = Vec::new();
        for i in 0..n {
            jobs.insert(Job::new(JobSpec::new(
                i,
                JobClass::Be,
                rv(2.0, 16.0, 0.0),
                0,
                100_000_000,
                0,
            )));
            arrivals.push(JobId(i));
        }
        let mut out = TickStats::default();
        sched.tick_into(0, &mut jobs, &arrivals, &mut out);
        assert_eq!(out.started.len(), n as usize, "bench state must saturate the cluster");
        for i in 0..n {
            jobs[JobId(i)].preemptions = 1; // at the cap: scanned, never chosen
        }
        jobs.insert(Job::new(JobSpec::new(n, JobClass::Te, rv(4.0, 32.0, 1.0), 1, 5, 0)));
        sched.tick_into(1, &mut jobs, &[JobId(n)], &mut out);
        let mut now = 2u64;
        let iters = if n >= 4096 { 2_000 } else { 20_000 };
        let m = measure_op(200, iters, || {
            sched.tick_into(now, &mut jobs, &[], &mut out);
            now += 1;
        });
        ops.push((
            if n == 256 { "plan_blocked_te_256" } else { "plan_blocked_te_4096" },
            m,
        ));
    }

    println!("per-op microbenches:");
    for (name, m) in &ops {
        println!("  {name}: {:.1} ns/op, {:.4} allocs/op", m.ns_per_op, m.allocs_per_op);
    }

    // Every one of the ops above is a steady-state hot-path operation:
    // the gate pins their alloc rate to zero collectively.
    let steady_allocs = ops.iter().map(|(_, m)| m.allocs_per_op).fold(0.0, f64::max);

    // --- end-to-end simulation throughput -----------------------------
    let jobs = 4096;
    let wl = common::paper_workload(1, jobs);
    for (name, policy) in common::paper_policies() {
        r.bench(&format!("sim 4096 jobs [{name}]"), 1, 5, || {
            let mut cfg = SimConfig::new(common::cluster(), policy);
            cfg.seed = 1;
            black_box(Simulator::new(cfg).run(&wl).makespan)
        });
    }

    // --- FitGpp victim scan -------------------------------------------
    for n in [256usize, 512, 1024] {
        let (cluster, jobs) = packed_cluster(n);
        let free: Vec<ResourceVec> = cluster.nodes.iter().map(|nd| nd.free).collect();
        let te = JobSpec::new(999_999, JobClass::Te, rv(16.0, 128.0, 4.0), 0, 5, 0);
        let oracle = |id: JobId| jobs[id].remaining_at(0);
        let vidx = VictimIndex::build(&cluster, &jobs);
        let mut scratch = PlanScratch::default();
        let mut rng = Pcg64::new(7);
        r.bench(&format!("fitgpp scan @{n} running"), 10, 50, || {
            let ctx = PolicyCtx {
                cluster: &cluster,
                jobs: &jobs,
                effective_free: &free,
                oracle_remaining: &oracle,
                predicted_remaining: &|_: JobId| 0.0,
                victims: &vidx,
            };
            black_box(fitgpp_policy::plan(&te, &ctx, &mut scratch, 4.0, Some(1), &mut rng))
        });
    }

    // --- placement search ablation --------------------------------------
    let (cluster, _jobs) = packed_cluster(512);
    let demand = rv(4.0, 32.0, 1.0);
    for (name, p) in [
        ("first-fit", Placement::FirstFit),
        ("best-fit", Placement::BestFit),
        ("worst-fit", Placement::WorstFit),
    ] {
        r.bench(&format!("placement {name} @512 jobs"), 10, 100, || {
            black_box(cluster.find_node(&demand, p))
        });
    }

    // --- placement *quality* ablation (slowdown impact, not latency) ----
    println!("\nplacement-policy ablation (TE p95 slowdown, 2048 jobs):");
    let wl_small = common::paper_workload(3, 2048);
    for (name, p) in [
        ("first-fit", Placement::FirstFit),
        ("best-fit", Placement::BestFit),
        ("worst-fit", Placement::WorstFit),
    ] {
        let mut cfg = SimConfig::new(common::cluster(), PolicyKind::FitGpp { s: 4.0, p_max: Some(1) });
        cfg.placement = p;
        let res = Simulator::new(cfg).run(&wl_small);
        println!(
            "  {name}: TE p95 {:.2}, BE p95 {:.2}, signals {}",
            res.slowdown_report().te.p95,
            res.slowdown_report().be.p95,
            res.sched_stats.preemption_signals
        );
    }

    // --- metrics -----------------------------------------------------------
    let mut rng = Pcg64::new(9);
    let xs: Vec<f64> = (0..65536).map(|_| rng.next_f64() * 100.0).collect();
    r.bench("percentiles 65536 samples", 3, 20, || {
        black_box(percentiles(&xs, &[50.0, 95.0, 99.0]))
    });

    // --- workload generation ------------------------------------------------
    r.bench("generate 4096-job workload", 1, 5, || {
        black_box(
            SyntheticWorkload::paper_section_4_2(5)
                .with_cluster(common::cluster())
                .with_num_jobs(4096)
                .generate()
                .len(),
        )
    });

    // --- machine-readable artifact ------------------------------------
    let op_objs: Vec<(&str, Json)> = ops
        .iter()
        .map(|(name, m)| {
            (
                *name,
                Json::obj(vec![
                    ("ns_per_op", Json::num(m.ns_per_op)),
                    ("allocs_per_op", Json::num(m.allocs_per_op)),
                ]),
            )
        })
        .collect();
    let json = Json::obj(vec![
        ("bench", Json::str("hotpath")),
        ("ops", Json::obj(op_objs)),
        ("steady_state_allocs_per_op", Json::num(steady_allocs)),
    ]);
    common::save_results_json("hotpath", &json);

    common::save_results("hotpath", &r.table("hotpath micro-benchmarks").to_text());
}
