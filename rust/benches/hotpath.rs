//! Scheduler hot-path micro-benchmarks (the §Perf L3 targets) plus design
//! ablations called out in DESIGN.md:
//!
//! * end-to-end simulation throughput (jobs/s) per policy
//! * FitGpp victim-scan latency at various running-job counts
//! * placement-search latency (first/best/worst fit ablation)
//! * percentile computation
//! * synthetic-workload generation

#[path = "common/mod.rs"]
mod common;

use fitgpp::benchkit::{black_box, BenchReport};
use fitgpp::cluster::{Cluster, ClusterSpec, Placement};
use fitgpp::job::{Job, JobClass, JobId, JobSpec};
use fitgpp::resources::ResourceVec;
use fitgpp::sched::policy::{fitgpp as fitgpp_policy, PolicyCtx, PolicyKind};
use fitgpp::sim::{SimConfig, Simulator};
use fitgpp::stats::rng::Pcg64;
use fitgpp::stats::summary::percentiles;
use fitgpp::workload::synthetic::SyntheticWorkload;

/// Build a cluster with `n_jobs` running BE jobs spread across 84 nodes.
fn packed_cluster(n_jobs: usize) -> (Cluster, fitgpp::job_table::JobTable) {
    let spec = ClusterSpec::pfn();
    let mut cluster = Cluster::new(&spec);
    let mut jobs = Vec::new();
    let mut rng = Pcg64::new(42);
    let mut placed = 0;
    while placed < n_jobs {
        let demand = ResourceVec::new(
            1.0 + rng.below(8) as f64,
            8.0 + rng.below(64) as f64,
            rng.below(3) as f64,
        );
        let Some(node) = cluster.find_node(&demand, Placement::FirstFit) else {
            break;
        };
        let s = JobSpec::new(placed as u32, JobClass::Be, demand, 0, 60, rng.below(20));
        let mut j = Job::new(s);
        j.start(node, 0);
        cluster.bind(JobId(placed as u32), demand, node);
        jobs.push(j);
        placed += 1;
    }
    (cluster, fitgpp::job_table::JobTable::from_jobs(jobs))
}

fn main() {
    let mut r = BenchReport::new();

    // --- end-to-end simulation throughput -----------------------------
    let jobs = 4096;
    let wl = common::paper_workload(1, jobs);
    for (name, policy) in common::paper_policies() {
        r.bench(&format!("sim 4096 jobs [{name}]"), 1, 5, || {
            let mut cfg = SimConfig::new(common::cluster(), policy);
            cfg.seed = 1;
            black_box(Simulator::new(cfg).run(&wl).makespan)
        });
    }

    // --- FitGpp victim scan -------------------------------------------
    for n in [256usize, 512, 1024] {
        let (cluster, jobs) = packed_cluster(n);
        let free: Vec<ResourceVec> = cluster.nodes.iter().map(|nd| nd.free).collect();
        let te = JobSpec::new(999_999, JobClass::Te, ResourceVec::new(16.0, 128.0, 4.0), 0, 5, 0);
        let oracle = |id: JobId| jobs[id].remaining;
        let mut rng = Pcg64::new(7);
        r.bench(&format!("fitgpp scan @{n} running"), 10, 50, || {
            let ctx = PolicyCtx {
                cluster: &cluster,
                jobs: &jobs,
                effective_free: &free,
                oracle_remaining: &oracle,
                predicted_remaining: &|_: JobId| 0.0,
            };
            black_box(fitgpp_policy::plan(&te, &ctx, 4.0, Some(1), &mut rng))
        });
    }

    // --- placement search ablation --------------------------------------
    let (cluster, _jobs) = packed_cluster(512);
    let demand = ResourceVec::new(4.0, 32.0, 1.0);
    for (name, p) in [
        ("first-fit", Placement::FirstFit),
        ("best-fit", Placement::BestFit),
        ("worst-fit", Placement::WorstFit),
    ] {
        r.bench(&format!("placement {name} @512 jobs"), 10, 100, || {
            black_box(cluster.find_node(&demand, p))
        });
    }

    // --- placement *quality* ablation (slowdown impact, not latency) ----
    println!("\nplacement-policy ablation (TE p95 slowdown, 2048 jobs):");
    let wl_small = common::paper_workload(3, 2048);
    for (name, p) in [
        ("first-fit", Placement::FirstFit),
        ("best-fit", Placement::BestFit),
        ("worst-fit", Placement::WorstFit),
    ] {
        let mut cfg = SimConfig::new(common::cluster(), PolicyKind::FitGpp { s: 4.0, p_max: Some(1) });
        cfg.placement = p;
        let res = Simulator::new(cfg).run(&wl_small);
        println!(
            "  {name}: TE p95 {:.2}, BE p95 {:.2}, signals {}",
            res.slowdown_report().te.p95,
            res.slowdown_report().be.p95,
            res.sched_stats.preemption_signals
        );
    }

    // --- metrics -----------------------------------------------------------
    let mut rng = Pcg64::new(9);
    let xs: Vec<f64> = (0..65536).map(|_| rng.next_f64() * 100.0).collect();
    r.bench("percentiles 65536 samples", 3, 20, || {
        black_box(percentiles(&xs, &[50.0, 95.0, 99.0]))
    });

    // --- workload generation ------------------------------------------------
    r.bench("generate 4096-job workload", 1, 5, || {
        black_box(
            SyntheticWorkload::paper_section_4_2(5)
                .with_cluster(common::cluster())
                .with_num_jobs(4096)
                .generate()
                .len(),
        )
    });

    common::save_results("hotpath", &r.table("hotpath micro-benchmarks").to_text());
}
