//! Table 1 + Fig. 3: percentiles of slowdown rates for FIFO / LRTP / RAND
//! / FitGpp(s=4, P=1) on the §4.2 synthetic workload.
//!
//! Paper values (for shape comparison):
//! ```text
//!              TE 50th  95th  99th   BE 50th  95th  99th
//! FIFO            9.38  33.4  48.5      2.78  4.89  8.21
//! LRTP            1.00  1.17  1.58      3.78  7.25  12.5
//! RAND            1.00  1.17  1.58      3.87  7.49  12.9
//! FitGpp (s=4)    1.00  1.15  1.54      3.28  6.06  10.3
//! ```
//!
//! Driven by the parallel sweep harness: the 4-policy × seed grid runs as
//! one work-stealing sweep with one generated workload per seed (the seed
//! repo generated and simulated each policy/class pair separately and
//! serially).

#[path = "common/mod.rs"]
mod common;

use fitgpp::job::JobClass;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sweep::SweepSpec;
use fitgpp::util::json::Json;

fn main() {
    let jobs = common::jobs_default();
    let seeds = common::seeds_default();
    let spec = SweepSpec::table1(jobs, &(0..seeds).map(|i| 100 + i as u64).collect::<Vec<_>>());
    println!(
        "table1_synthetic: {jobs} jobs x {seeds} seeds on {} threads (FITGPP_JOBS / FITGPP_SEEDS / FITGPP_THREADS to scale)",
        spec.threads_effective()
    );
    let res = spec.run();

    let fifo_te = res.pooled_percentiles(PolicyKind::Fifo, JobClass::Te);
    let fifo_be = res.pooled_percentiles(PolicyKind::Fifo, JobClass::Be);
    let fg = PolicyKind::FitGpp { s: 4.0, p_max: Some(1) };
    let fitgpp_te = res.pooled_percentiles(fg, JobClass::Te);
    let fitgpp_be = res.pooled_percentiles(fg, JobClass::Be);

    let mut out = res.table1("Table 1: Percentiles of slowdown rates").to_text();
    out.push_str(&format!(
        "\nheadline: FitGpp reduces FIFO's TE p95 by {:.1}% (paper: 96.6%)\n\
         BE p50 changes by {:+.1}% (paper: +18.0%), BE p95 by {:+.1}% (paper: +23.9%)\n",
        (1.0 - fitgpp_te.p95 / fifo_te.p95) * 100.0,
        (fitgpp_be.p50 / fifo_be.p50 - 1.0) * 100.0,
        (fitgpp_be.p95 / fifo_be.p95 - 1.0) * 100.0,
    ));
    out.push_str(&format!(
        "sweep: {} cells, {:.1}s wall on {} threads ({:.1}s serial-equivalent sim time)\n",
        res.cells.len(),
        res.wall.as_secs_f64(),
        res.threads,
        res.total_cell_wall().as_secs_f64()
    ));
    common::save_results("table1_synthetic", &out);

    // Machine-readable perf + headline numbers, committed across PRs.
    let minutes: u64 = res.cells.iter().map(|c| c.makespan).sum();
    common::save_results_json(
        "table1_synthetic",
        &Json::obj(vec![
            ("bench", Json::str("table1_synthetic")),
            ("jobs", Json::num(jobs as f64)),
            ("seeds", Json::num(seeds as f64)),
            ("cells", Json::num(res.cells.len() as f64)),
            ("threads", Json::num(res.threads as f64)),
            ("wall_sec", Json::num(res.wall.as_secs_f64())),
            (
                "sim_minutes_per_sec",
                Json::num(minutes as f64 / res.wall.as_secs_f64().max(1e-12)),
            ),
            (
                "te_p95_reduction_vs_fifo",
                Json::num(1.0 - fitgpp_te.p95 / fifo_te.p95),
            ),
            (
                "be_p50_change_vs_fifo",
                Json::num(fitgpp_be.p50 / fifo_be.p50 - 1.0),
            ),
        ]),
    );
}
