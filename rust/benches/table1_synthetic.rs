//! Table 1 + Fig. 3: percentiles of slowdown rates for FIFO / LRTP / RAND
//! / FitGpp(s=4, P=1) on the §4.2 synthetic workload.
//!
//! Paper values (for shape comparison):
//! ```text
//!              TE 50th  95th  99th   BE 50th  95th  99th
//! FIFO            9.38  33.4  48.5      2.78  4.89  8.21
//! LRTP            1.00  1.17  1.58      3.78  7.25  12.5
//! RAND            1.00  1.17  1.58      3.87  7.49  12.9
//! FitGpp (s=4)    1.00  1.15  1.54      3.28  6.06  10.3
//! ```

#[path = "common/mod.rs"]
mod common;

use fitgpp::job::JobClass;
use fitgpp::metrics::{slowdown_table, Percentiles, SlowdownReport};
use std::time::Instant;

fn main() {
    let jobs = common::jobs_default();
    let seeds = common::seeds_default();
    println!("table1_synthetic: {jobs} jobs x {seeds} seeds (FITGPP_JOBS / FITGPP_SEEDS to scale)");

    let mut rows = Vec::new();
    let mut fifo_te_p95 = f64::NAN;
    let mut fifo_be = Percentiles { p50: f64::NAN, p95: f64::NAN, p99: f64::NAN };
    let mut fitgpp_te_p95 = f64::NAN;
    let mut fitgpp_be = fifo_be;
    for (name, policy) in common::paper_policies() {
        let t0 = Instant::now();
        let te = Percentiles::of(&common::pooled_slowdowns(policy, seeds, jobs, JobClass::Te));
        let be = Percentiles::of(&common::pooled_slowdowns(policy, seeds, jobs, JobClass::Be));
        eprintln!("  {name}: {:.1}s", t0.elapsed().as_secs_f64());
        if name == "FIFO" {
            fifo_te_p95 = te.p95;
            fifo_be = be;
        }
        if name.starts_with("FitGpp") {
            fitgpp_te_p95 = te.p95;
            fitgpp_be = be;
        }
        rows.push((name, SlowdownReport { te, be }));
    }
    let named: Vec<(&str, SlowdownReport)> = rows.iter().map(|(n, r)| (n.as_str(), *r)).collect();
    let mut out = slowdown_table("Table 1: Percentiles of slowdown rates", &named).to_text();
    out.push_str(&format!(
        "\nheadline: FitGpp reduces FIFO's TE p95 by {:.1}% (paper: 96.6%)\n\
         BE p50 changes by {:+.1}% (paper: +18.0%), BE p95 by {:+.1}% (paper: +23.9%)\n",
        (1.0 - fitgpp_te_p95 / fifo_te_p95) * 100.0,
        (fitgpp_be.p50 / fifo_be.p50 - 1.0) * 100.0,
        (fitgpp_be.p95 / fifo_be.p95 - 1.0) * 100.0,
    ));
    common::save_results("table1_synthetic", &out);
}
