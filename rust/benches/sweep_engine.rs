//! Evaluation-substrate speedup on the Table-1 synthetic grid: the
//! event-horizon engine + parallel sweep harness versus the seed's
//! serial per-minute loop.
//!
//! Three measurements over the *same* grid (4 §4.1 policies × seeds, §4.2
//! workloads, identical results asserted cell-by-cell):
//!
//! 1. `per-minute, serial` — the baseline: `SimEngine::PerMinute`, one
//!    thread. This is exactly how the seed repository ran its evaluation.
//! 2. `event-horizon, serial` — isolates the engine win (quiescent spans
//!    fast-forwarded in bulk).
//! 3. `event-horizon, parallel` — the shipped substrate: engine win ×
//!    work-stealing thread parallelism.
//!
//! Scale knobs: `FITGPP_JOBS` (default 512), `FITGPP_SEEDS` (default 4),
//! `FITGPP_NODES` (default 2 — a small cluster keeps the event density per
//! simulated minute low, which is also the regime where minute-ticking
//! wastes the most work), `FITGPP_THREADS`.

#[path = "common/mod.rs"]
mod common;

use fitgpp::benchkit::env_usize;
use fitgpp::cluster::ClusterSpec;
use fitgpp::sim::SimEngine;
use fitgpp::sweep::{SweepResult, SweepSpec};
use fitgpp::util::json::Json;
use fitgpp::util::table::Table;

fn grid(jobs: usize, seeds: usize, nodes: usize) -> SweepSpec {
    SweepSpec::table1(jobs, &(0..seeds).map(|i| 100 + i as u64).collect::<Vec<_>>())
        .with_cluster(ClusterSpec::tiny(nodes))
}

fn total_simulated_minutes(res: &SweepResult) -> u64 {
    res.cells.iter().map(|c| c.makespan).sum()
}

fn main() {
    let jobs = env_usize("FITGPP_JOBS", 512);
    let seeds = env_usize("FITGPP_SEEDS", 4);
    let nodes = env_usize("FITGPP_NODES", 2);
    let spec = grid(jobs, seeds, nodes);
    println!(
        "sweep_engine: Table-1 grid, {} cells ({jobs} jobs x {seeds} seeds x 4 policies, {nodes} nodes), {} threads available",
        spec.cells().len(),
        spec.threads_effective()
    );

    // 1. Baseline: per-minute drive mode, one thread. (This mode also
    //    benefits from the EventClock scan-skip, so cross-PR comparisons
    //    should track the absolute sim_minutes_per_sec in the JSON rather
    //    than the relative speedups, whose baseline improves over time.)
    let pm = spec
        .clone()
        .with_engine(SimEngine::PerMinute)
        .with_threads(1)
        .run();
    // 2. Engine isolated: event-horizon, still one thread.
    let eh_serial = spec
        .clone()
        .with_engine(SimEngine::EventHorizon)
        .with_threads(1)
        .run();
    // 3. The shipped substrate: event-horizon on all cores.
    let eh_par = spec.clone().with_engine(SimEngine::EventHorizon).run();

    // The grids must agree cell-for-cell (same reports; wall clock is the
    // only column allowed to differ), or the speedup below is meaningless.
    assert_eq!(
        pm.to_csv_without_wall(),
        eh_serial.to_csv_without_wall(),
        "engines disagree on the grid"
    );
    assert_eq!(
        pm.to_csv_without_wall(),
        eh_par.to_csv_without_wall(),
        "parallel run disagrees with the serial grid"
    );

    let pm_sim = pm.total_cell_wall().as_secs_f64();
    let eh_sim = eh_serial.total_cell_wall().as_secs_f64();
    let minutes = total_simulated_minutes(&pm) as f64;
    let ff: u64 = eh_serial.cells.iter().map(|c| c.fast_forwarded_ticks).sum();

    let mut t = Table::new(
        "Table-1 grid: evaluation-substrate wall clock",
        &["configuration", "wall (s)", "sim-only (s)", "speedup vs baseline"],
    );
    t.row(vec![
        "per-minute, serial (reference drive mode)".into(),
        format!("{:.2}", pm.wall.as_secs_f64()),
        format!("{:.2}", pm_sim),
        "1.00x".into(),
    ]);
    t.row(vec![
        "event-horizon, serial".into(),
        format!("{:.2}", eh_serial.wall.as_secs_f64()),
        format!("{:.2}", eh_sim),
        format!("{:.2}x", pm.wall.as_secs_f64() / eh_serial.wall.as_secs_f64()),
    ]);
    t.row(vec![
        format!("event-horizon, {} threads", eh_par.threads),
        format!("{:.2}", eh_par.wall.as_secs_f64()),
        "-".into(),
        format!("{:.2}x", pm.wall.as_secs_f64() / eh_par.wall.as_secs_f64()),
    ]);

    let mut out = t.to_text();
    out.push_str(&format!(
        "\nsimulated minutes in grid: {:.0}; bulk fast-forwarded by event horizon: {ff} ({:.1}%)\n",
        minutes,
        100.0 * ff as f64 / minutes.max(1.0)
    ));
    out.push_str(&format!(
        "engine-only speedup (sim time, serial): {:.2}x\n",
        pm_sim / eh_sim
    ));
    out.push_str(&format!(
        "total substrate speedup (event-horizon + {}-thread sweep vs per-minute serial): {:.2}x\n",
        eh_par.threads,
        pm.wall.as_secs_f64() / eh_par.wall.as_secs_f64()
    ));
    common::save_results("sweep_engine", &out);

    // Machine-readable perf trajectory, committed across PRs.
    let config_row = |label: &str, res: &SweepResult, sim_only: Option<f64>| {
        Json::obj(vec![
            ("label", Json::str(label)),
            ("wall_sec", Json::num(res.wall.as_secs_f64())),
            ("sim_only_sec", sim_only.map(Json::num).unwrap_or(Json::Null)),
            ("threads", Json::num(res.threads as f64)),
            (
                "sim_minutes_per_sec",
                Json::num(minutes / res.wall.as_secs_f64().max(1e-12)),
            ),
            (
                "speedup_vs_baseline",
                Json::num(pm.wall.as_secs_f64() / res.wall.as_secs_f64().max(1e-12)),
            ),
        ])
    };
    common::save_results_json(
        "sweep_engine",
        &Json::obj(vec![
            ("bench", Json::str("sweep_engine")),
            (
                "grid",
                Json::obj(vec![
                    ("jobs", Json::num(jobs as f64)),
                    ("seeds", Json::num(seeds as f64)),
                    ("nodes", Json::num(nodes as f64)),
                    ("cells", Json::num(pm.cells.len() as f64)),
                    ("simulated_minutes", Json::num(minutes)),
                ]),
            ),
            (
                "configurations",
                Json::Arr(vec![
                    config_row("per-minute serial (reference drive mode)", &pm, Some(pm_sim)),
                    config_row("event-horizon serial", &eh_serial, Some(eh_sim)),
                    config_row("event-horizon parallel", &eh_par, None),
                ]),
            ),
            (
                "fast_forwarded_fraction",
                Json::num(ff as f64 / minutes.max(1.0)),
            ),
            (
                "engine_only_speedup_sim_time",
                Json::num(pm_sim / eh_sim.max(1e-12)),
            ),
        ]),
    );
}
