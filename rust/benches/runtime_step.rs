//! PJRT runtime benchmarks (the §Perf L1/L2 hot path as executed from
//! rust): artifact compile time, train-step latency, checkpoint
//! serialization throughput. Skips gracefully when artifacts are absent.

#[path = "common/mod.rs"]
mod common;

use fitgpp::benchkit::{black_box, BenchReport};
use fitgpp::runtime::{self, Engine, Manifest, Trainer};

fn main() {
    if !runtime::artifacts_available() {
        println!("runtime_step: artifacts not built (run `make artifacts`); skipping");
        return;
    }
    let engine = Engine::cpu().expect("PJRT CPU client");
    let manifest = Manifest::load(&runtime::artifacts_dir()).expect("manifest");
    let mut r = BenchReport::new();

    for variant in ["tiny", "small"] {
        let v = manifest.variant(variant).unwrap();
        println!(
            "{variant}: {} params, batch {}x{}",
            v.param_count(),
            v.tokens.shape[0],
            v.tokens.shape[1]
        );
        // Compile latency (one-off per worker in live mode).
        r.bench(&format!("compile {variant}"), 0, 3, || {
            black_box(
                engine
                    .load_hlo_text(&manifest.artifact_path(&v.train_step))
                    .is_ok(),
            )
        });
        // Step latency.
        let mut trainer = Trainer::new(&engine, &manifest, variant, 1).unwrap();
        r.bench(&format!("train step {variant}"), 3, 10, || {
            black_box(trainer.step_synthetic().unwrap())
        });
        // Tokens/s derived figure.
        if let Some(m) = r.get(&format!("train step {variant}")) {
            let toks = (v.tokens.shape[0] * v.tokens.shape[1]) as f64;
            println!(
                "  {variant}: {:.0} tokens/s, {:.1} steps/s",
                toks / m.median.as_secs_f64(),
                1.0 / m.median.as_secs_f64()
            );
        }
        // Checkpoint (the grace-period work).
        let ckpt = trainer.checkpoint().unwrap();
        let bytes = ckpt.to_bytes();
        println!("  checkpoint: {} bytes", bytes.len());
        r.bench(&format!("checkpoint serialize {variant}"), 3, 10, || {
            black_box(trainer.checkpoint().unwrap().to_bytes().len())
        });
        r.bench(&format!("checkpoint parse {variant}"), 3, 10, || {
            black_box(fitgpp::runtime::Checkpoint::from_bytes(&bytes).unwrap().step)
        });
    }

    common::save_results("runtime_step", &r.table("PJRT runtime benchmarks").to_text());
}
