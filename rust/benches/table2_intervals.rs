//! Table 2: re-scheduling intervals (minutes between a victim vacating
//! and restarting). Paper: FitGpp's median is half of LRTP/RAND's.
//!
//! ```text
//!           50th 75th 95th 99th
//! LRTP       4.0  4.0  5.0  7.0
//! RAND       4.0  4.0  6.0  7.0
//! FitGpp     2.0  2.0  4.0  6.0
//! ```

#[path = "common/mod.rs"]
mod common;

use fitgpp::metrics::{intervals_table, IntervalsReport};
use fitgpp::stats::summary::percentiles;

fn main() {
    let jobs = common::jobs_default();
    let seeds = common::seeds_default();
    println!("table2_intervals: {jobs} jobs x {seeds} seeds");

    let mut rows = Vec::new();
    for (name, policy) in common::paper_policies() {
        if !policy.preempts() {
            continue; // FIFO has no intervals
        }
        let mut iv: Vec<f64> = Vec::new();
        for s in 0..seeds {
            let wl = common::paper_workload(100 + s as u64, jobs);
            iv.extend(common::run_policy(&wl, policy, s as u64).resched_intervals());
        }
        let rep = if iv.is_empty() {
            IntervalsReport { p50: f64::NAN, p75: f64::NAN, p95: f64::NAN, p99: f64::NAN, count: 0 }
        } else {
            let v = percentiles(&iv, &[50.0, 75.0, 95.0, 99.0]);
            IntervalsReport { p50: v[0], p75: v[1], p95: v[2], p99: v[3], count: iv.len() }
        };
        rows.push((name, rep));
    }
    let named: Vec<(&str, IntervalsReport)> = rows.iter().map(|(n, r)| (n.as_str(), *r)).collect();
    let out = intervals_table("Table 2: Re-scheduling intervals [min]", &named).to_text();
    common::save_results("table2_intervals", &out);
}
