//! Fig. 6: 95th-percentile slowdown vs the proportion of TE jobs in the
//! workload. Paper shape: TE slowdown grows with the TE share (their
//! combined demand eventually exceeds capacity); FitGpp dominates the
//! baselines at every ratio while keeping BE slowdown low.

#[path = "common/mod.rs"]
mod common;

use fitgpp::job::JobClass;
use fitgpp::stats::summary::percentile;
use fitgpp::util::table::Table;
use fitgpp::workload::synthetic::SyntheticWorkload;

fn main() {
    let jobs = common::jobs_default();
    println!("fig6_te_ratio: {jobs} jobs per point");

    let mut t = Table::new(
        "Fig. 6: p95 slowdown vs TE-job proportion",
        &["TE %", "policy", "TE p95", "BE p95"],
    );
    for frac in [0.1, 0.2, 0.3, 0.5, 0.7] {
        let wl = SyntheticWorkload::paper_section_4_2(7)
            .with_cluster(common::cluster())
            .with_num_jobs(jobs)
            .with_te_fraction(frac)
            .generate();
        for (name, policy) in common::paper_policies() {
            let res = common::run_policy(&wl, policy, 1);
            let te = res.slowdowns(JobClass::Te);
            let be = res.slowdowns(JobClass::Be);
            t.row(vec![
                format!("{:.0}", frac * 100.0),
                name,
                format!("{:.2}", percentile(&te, 95.0)),
                format!("{:.2}", percentile(&be, 95.0)),
            ]);
        }
    }
    common::save_results("fig6_te_ratio", &t.to_text());
}
