//! Fig. 6: 95th-percentile slowdown vs the proportion of TE jobs in the
//! workload. Paper shape: TE slowdown grows with the TE share (their
//! combined demand eventually exceeds capacity); FitGpp dominates the
//! baselines at every ratio while keeping BE slowdown low.
//!
//! Driven by the parallel sweep harness: the TE-ratio axis is a first-class
//! grid dimension, so all ratio × policy cells run as one work-stealing
//! sweep with one workload generated per ratio.

#[path = "common/mod.rs"]
mod common;

use fitgpp::job::JobClass;
use fitgpp::sweep::{paper_policies, SweepSpec};
use fitgpp::util::table::Table;

fn main() {
    let jobs = common::jobs_default();
    let ratios = vec![0.1, 0.2, 0.3, 0.5, 0.7];
    let spec = SweepSpec::new(common::cluster(), paper_policies())
        .with_num_jobs(jobs)
        .with_seeds(vec![7])
        .with_te_ratios(ratios.clone());
    println!(
        "fig6_te_ratio: {jobs} jobs per point, {} threads",
        spec.threads_effective()
    );
    let res = spec.run();

    let mut t = Table::new(
        "Fig. 6: p95 slowdown vs TE-job proportion",
        &["TE %", "policy", "TE p95", "BE p95"],
    );
    for &frac in &ratios {
        for policy in paper_policies() {
            let te = res.pooled_percentiles_where(
                |c| c.policy == policy && c.te_ratio == frac,
                JobClass::Te,
            );
            let be = res.pooled_percentiles_where(
                |c| c.policy == policy && c.te_ratio == frac,
                JobClass::Be,
            );
            t.row(vec![
                format!("{:.0}", frac * 100.0),
                policy.name(),
                format!("{:.2}", te.p95),
                format!("{:.2}", be.p95),
            ]);
        }
    }
    common::report_sweep(&res);
    common::save_results("fig6_te_ratio", &t.to_text());
}
