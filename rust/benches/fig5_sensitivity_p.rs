//! Fig. 5: FitGpp slowdown percentiles vs the per-job preemption cap P.
//! Paper shape: both TE and BE slowdowns are essentially independent of P
//! (FitGpp rarely needs to preempt the same job twice).

#[path = "common/mod.rs"]
mod common;

use fitgpp::job::JobClass;
use fitgpp::metrics::Percentiles;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::util::table::Table;

fn main() {
    let jobs = common::jobs_default();
    let seeds = common::seeds_default();
    println!("fig5_sensitivity_p: {jobs} jobs x {seeds} seeds (s = 4)");

    let mut t = Table::new(
        "Fig. 5: FitGpp slowdown vs P",
        &["P", "TE p50", "TE p95", "TE p99", "BE p50", "BE p95", "BE p99"],
    );
    for p in [Some(1u32), Some(2), Some(4), Some(8), None] {
        let policy = PolicyKind::FitGpp { s: 4.0, p_max: p };
        let te = Percentiles::of(&common::pooled_slowdowns(policy, seeds, jobs, JobClass::Te));
        let be = Percentiles::of(&common::pooled_slowdowns(policy, seeds, jobs, JobClass::Be));
        t.row(vec![
            p.map(|x| x.to_string()).unwrap_or("inf".into()),
            format!("{:.3}", te.p50),
            format!("{:.3}", te.p95),
            format!("{:.3}", te.p99),
            format!("{:.2}", be.p50),
            format!("{:.2}", be.p95),
            format!("{:.2}", be.p99),
        ]);
    }
    common::save_results("fig5_sensitivity_p", &t.to_text());
}
