//! Fig. 5: FitGpp slowdown percentiles vs the per-job preemption cap P.
//! Paper shape: both TE and BE slowdowns are essentially independent of P
//! (FitGpp rarely needs to preempt the same job twice).
//!
//! Driven by the parallel sweep harness (one work-stealing grid, workloads
//! shared across the P points).

#[path = "common/mod.rs"]
mod common;

use fitgpp::job::JobClass;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sweep::SweepSpec;
use fitgpp::util::table::Table;

fn main() {
    let jobs = common::jobs_default();
    let seeds = common::seeds_default();
    let p_grid = [Some(1u32), Some(2), Some(4), Some(8), None];
    let spec = SweepSpec::new(common::cluster(), Vec::new())
        .fitgpp_p_grid(4.0, &p_grid)
        .with_num_jobs(jobs)
        .with_seeds((0..seeds).map(|i| 100 + i as u64).collect());
    println!(
        "fig5_sensitivity_p: {jobs} jobs x {seeds} seeds (s = 4), {} threads",
        spec.threads_effective()
    );
    let res = spec.run();

    let mut t = Table::new(
        "Fig. 5: FitGpp slowdown vs P",
        &["P", "TE p50", "TE p95", "TE p99", "BE p50", "BE p95", "BE p99"],
    );
    for &p in &p_grid {
        let policy = PolicyKind::FitGpp { s: 4.0, p_max: p };
        let te = res.pooled_percentiles(policy, JobClass::Te);
        let be = res.pooled_percentiles(policy, JobClass::Be);
        t.row(vec![
            p.map(|x| x.to_string()).unwrap_or("inf".into()),
            format!("{:.3}", te.p50),
            format!("{:.3}", te.p95),
            format!("{:.3}", te.p99),
            format!("{:.2}", be.p50),
            format!("{:.2}", be.p95),
            format!("{:.2}", be.p99),
        ]);
    }
    common::report_sweep(&res);
    common::save_results("fig5_sensitivity_p", &t.to_text());
}
