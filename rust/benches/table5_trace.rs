//! Table 5 + Fig. 8: slowdown percentiles on the institution trace
//! (§4.4; synthesized stand-in, DESIGN.md §3). Paper shape: preemptive
//! policies crush FIFO's enormous TE tail (235 → ~2 at p50) and FitGpp
//! *also beats FIFO on BE* (the re-arrangement effect: 16.2 → 11.4 p50).

#[path = "common/mod.rs"]
mod common;

use fitgpp::job::JobClass;
use fitgpp::metrics::{slowdown_table, Percentiles, SlowdownReport};
use fitgpp::workload::trace::Trace;

fn main() {
    let jobs = common::jobs_default();
    println!("table5_trace: {jobs}-job institution trace");
    let wl = Trace::synthesize_institution(7, jobs);
    eprintln!(
        "trace: {} jobs, {:.1}% TE, span {:.1} days",
        wl.len(),
        wl.te_fraction() * 100.0,
        wl.submit_span() as f64 / 1440.0
    );

    let mut rows = Vec::new();
    for (name, policy) in common::paper_policies() {
        let res = common::run_policy(&wl, policy, 3);
        rows.push((
            name,
            SlowdownReport {
                te: Percentiles::of(&res.slowdowns(JobClass::Te)),
                be: Percentiles::of(&res.slowdowns(JobClass::Be)),
            },
        ));
    }
    let named: Vec<(&str, SlowdownReport)> = rows.iter().map(|(n, r)| (n.as_str(), *r)).collect();
    let out = slowdown_table(
        "Table 5: Percentiles of slowdown rates (institution trace)",
        &named,
    )
    .to_text();
    common::save_results("table5_trace", &out);
}
