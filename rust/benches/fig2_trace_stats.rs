//! Fig. 2: statistics of the jobs on the institution cluster — execution
//! time / CPU / RAM / GPU distributions per class. Regenerated from the
//! synthesized trace (DESIGN.md §3 documents the substitution).

#[path = "common/mod.rs"]
mod common;

use fitgpp::job::JobClass;
use fitgpp::stats::summary::Summary;
use fitgpp::util::table::Table;
use fitgpp::workload::trace::Trace;

fn main() {
    let jobs = common::jobs_default();
    let wl = Trace::synthesize_institution(7, jobs);
    let mut t = Table::new(
        "Fig. 2: job statistics on the (synthesized) institution cluster",
        &["class", "metric", "mean", "p50", "p95", "p99", "max"],
    );
    for class in [JobClass::Te, JobClass::Be] {
        let sel: Vec<&fitgpp::job::JobSpec> = wl.of_class(class).collect();
        let metrics: [(&str, Vec<f64>); 4] = [
            ("exec [min]", sel.iter().map(|j| j.exec_time as f64).collect()),
            ("cpu", sel.iter().map(|j| j.demand.cpu).collect()),
            ("ram [GB]", sel.iter().map(|j| j.demand.ram_gb).collect()),
            ("gpu", sel.iter().map(|j| j.demand.gpu).collect()),
        ];
        for (name, xs) in metrics {
            let s = Summary::of(&xs);
            t.row(vec![
                class.as_str().into(),
                name.into(),
                format!("{:.1}", s.mean),
                format!("{:.1}", s.p50),
                format!("{:.1}", s.p95),
                format!("{:.1}", s.p99),
                format!("{:.1}", s.max),
            ]);
        }
    }
    let mut out = t.to_text();
    out.push_str(&format!(
        "\njobs: {} ({:.1}% TE), arrival span {:.1} days\n",
        wl.len(),
        wl.te_fraction() * 100.0,
        wl.submit_span() as f64 / 1440.0
    ));
    common::save_results("fig2_trace_stats", &out);
}
