//! Wire-service throughput: commands/sec over a unix socket and event
//! fan-out delivery rate to concurrent subscribers.
//!
//! One in-process `serve` session on a temp UDS; two measurements:
//!
//! * **commands/sec** — one client pipelines `FITGPP_SERVE_CMDS` submit
//!   requests and reads every ack back; the rate is acked commands over
//!   the wall time of the whole round trip.
//! * **event fan-out events/sec** — four subscribed connections while a
//!   driver submits `FITGPP_SERVE_JOBS` one-minute jobs; each subscriber
//!   reads until it has seen every job finish, and the rate is total
//!   event lines delivered (all subscribers summed) over the wall time.
//!
//! Results land in `BENCH_serve.json` (`commands_per_sec`,
//! `events_per_sec`), floor-gated by `scripts/perf_gate.sh` against
//! `BENCH_serve_baseline.json`. The queue bound is set far above the
//! line volume, so a single drop (a `lagged` notice) fails the bench —
//! throughput numbers must describe complete delivery.

#[path = "common/mod.rs"]
mod common;

#[cfg(unix)]
fn main() {
    bench::run();
}

#[cfg(not(unix))]
fn main() {
    eprintln!("serve bench requires unix-domain sockets; skipped");
}

#[cfg(unix)]
mod bench {
    use super::common;
    use fitgpp::benchkit::env_usize;
    use fitgpp::cluster::ClusterSpec;
    use fitgpp::sched::policy::PolicyKind;
    use fitgpp::serve::server::{self, ServeConfig};
    use fitgpp::sim::SimConfig;
    use fitgpp::util::json::Json;
    use fitgpp::workload::source::WorkloadSource;
    use fitgpp::workload::Workload;
    use std::io::{BufRead, BufReader, Write};
    use std::os::unix::net::UnixStream;
    use std::path::PathBuf;
    use std::sync::mpsc;
    use std::thread;
    use std::time::{Duration, Instant};

    const FANOUT_SUBSCRIBERS: usize = 4;
    const FANOUT_ID_BASE: u64 = 10_000_000;

    fn connect(sock: &PathBuf) -> (BufReader<UnixStream>, UnixStream) {
        let mut tries = 0;
        let stream = loop {
            match UnixStream::connect(sock) {
                Ok(s) => break s,
                Err(_) if tries < 500 => {
                    tries += 1;
                    thread::sleep(Duration::from_millis(10));
                }
                Err(e) => panic!("serve bench: socket never came up: {e}"),
            }
        };
        let reader = BufReader::new(stream.try_clone().expect("clone uds"));
        (reader, stream)
    }

    /// Read one line and panic if it is a `lagged` notice — a drop means
    /// the measurement no longer describes complete delivery.
    fn read_line(reader: &mut BufReader<UnixStream>, line: &mut String) -> Json {
        line.clear();
        assert!(reader.read_line(line).expect("read") > 0, "server closed early");
        let v = Json::parse(line).expect("json line");
        assert_ne!(v.get("type").as_str(), Some("lagged"), "bench dropped events: {line}");
        v
    }

    pub fn run() {
        let sock = std::env::temp_dir()
            .join(format!("fitgpp-serve-bench-{}.sock", std::process::id()));
        let mut cfg = ServeConfig::new(SimConfig::new(ClusterSpec::tiny(4), PolicyKind::Fifo));
        cfg.uds = Some(sock.clone());
        // Far above the total line volume: any overflow is a bench bug.
        cfg.queue_cap = 1 << 17;
        let server = thread::spawn(move || {
            let workload = Workload::new(Vec::new());
            let mut source = WorkloadSource::new(&workload);
            server::run(cfg, &mut source).expect("serve")
        });

        // --- commands/sec: pipelined submits, every ack read back -------
        let n_cmds = env_usize("FITGPP_SERVE_CMDS", 4000);
        let (mut reader, mut writer) = connect(&sock);
        let mut line = String::new();
        assert_eq!(read_line(&mut reader, &mut line).get("type").as_str(), Some("hello"));
        let t0 = Instant::now();
        for i in 0..n_cmds {
            writeln!(
                writer,
                r#"{{"cmd":"submit","id":{i},"class":"BE","cpu":1,"ram_gb":1,"gpu":0,"exec_time":1,"seq":{i}}}"#
            )
            .expect("write submit");
        }
        let mut acked = 0usize;
        while acked < n_cmds {
            if read_line(&mut reader, &mut line).get("type").as_str() == Some("ack") {
                acked += 1;
            }
        }
        let commands_per_sec = n_cmds as f64 / t0.elapsed().as_secs_f64();
        println!("commands/sec over uds: {commands_per_sec:.0} ({n_cmds} pipelined submits)");
        drop(writer);
        drop(reader);

        // --- event fan-out: subscribers must see every job finish -------
        let n_jobs = env_usize("FITGPP_SERVE_JOBS", 4000);
        let (ready_tx, ready_rx) = mpsc::channel::<()>();
        let subs: Vec<_> = (0..FANOUT_SUBSCRIBERS)
            .map(|_| {
                let sock = sock.clone();
                let ready = ready_tx.clone();
                thread::spawn(move || {
                    let (mut reader, mut writer) = connect(&sock);
                    let mut line = String::new();
                    assert_eq!(
                        read_line(&mut reader, &mut line).get("type").as_str(),
                        Some("hello")
                    );
                    writeln!(writer, r#"{{"cmd":"subscribe","seq":1}}"#).expect("subscribe");
                    loop {
                        if read_line(&mut reader, &mut line).get("type").as_str() == Some("ack") {
                            break;
                        }
                    }
                    ready.send(()).expect("ready");
                    let mut lines = 0u64;
                    let mut finished = 0usize;
                    while finished < n_jobs {
                        let v = read_line(&mut reader, &mut line);
                        lines += 1;
                        if v.get("type").as_str() == Some("finished")
                            && v.get("job").as_u64().is_some_and(|j| j >= FANOUT_ID_BASE)
                        {
                            finished += 1;
                        }
                    }
                    lines
                })
            })
            .collect();
        for _ in 0..FANOUT_SUBSCRIBERS {
            ready_rx.recv().expect("subscriber up");
        }
        let (mut reader, mut writer) = connect(&sock);
        assert_eq!(read_line(&mut reader, &mut line).get("type").as_str(), Some("hello"));
        let t0 = Instant::now();
        for i in 0..n_jobs {
            writeln!(
                writer,
                r#"{{"cmd":"submit","id":{},"class":"BE","cpu":1,"ram_gb":1,"gpu":0,"exec_time":1,"seq":{i}}}"#,
                FANOUT_ID_BASE + i as u64
            )
            .expect("write submit");
        }
        let mut acked = 0usize;
        while acked < n_jobs {
            if read_line(&mut reader, &mut line).get("type").as_str() == Some("ack") {
                acked += 1;
            }
        }
        let mut delivered = 0u64;
        for s in subs {
            delivered += s.join().expect("subscriber");
        }
        let events_per_sec = delivered as f64 / t0.elapsed().as_secs_f64();
        println!(
            "event fan-out: {events_per_sec:.0} events/sec delivered \
             ({delivered} lines to {FANOUT_SUBSCRIBERS} subscribers, {n_jobs} jobs)"
        );

        writeln!(writer, r#"{{"cmd":"shutdown"}}"#).expect("shutdown");
        let outcome = server.join().expect("server thread");
        assert_eq!(
            outcome.stats.events_dropped, 0,
            "bench must measure complete delivery"
        );
        assert_eq!(outcome.result.metrics.completed as usize, n_cmds + n_jobs);

        let json = Json::obj(vec![
            ("bench", Json::str("serve")),
            ("commands_per_sec", Json::num(commands_per_sec)),
            ("events_per_sec", Json::num(events_per_sec)),
            ("subscribers", Json::num(FANOUT_SUBSCRIBERS as f64)),
        ]);
        common::save_results_json("serve", &json);
    }
}
