//! Wire-service throughput and latency: commands/sec over a unix
//! socket, event fan-out delivery rate, ack latency percentiles, the
//! encode hot path's allocation count, and a fan-out batch-size sweep.
//!
//! One in-process `serve` session on a temp UDS; measurements:
//!
//! * **encode ns/op + allocs/op** — the direct JSONL encoders
//!   (`JsonLineEncoder`, `ResponseEncoder`) hammered in-process before
//!   any server thread starts; after warmup the encode path must not
//!   allocate at all (`steady_state_allocs_per_op`, pinned to 0 by
//!   `scripts/perf_gate.sh`).
//! * **commands/sec** — one client pipelines `FITGPP_SERVE_CMDS` submit
//!   requests and reads every ack back; the rate is acked commands over
//!   the wall time of the whole round trip.
//! * **ack p50/p99 µs** — a closed-loop client submits
//!   `FITGPP_SERVE_LAT` jobs one at a time, timing each submit→ack round
//!   trip into a quantile sketch (`ack_p50_us`, `ack_p99_us`).
//! * **event fan-out events/sec** — four subscribed connections while a
//!   driver submits `FITGPP_SERVE_JOBS` one-minute jobs; each subscriber
//!   reads until it has seen every job finish, and the rate is total
//!   event lines delivered (all subscribers summed) over the wall time.
//!   Auto-snapshots run throughout, so the reported
//!   `snapshot_stall_ms` shows what snapshotting costs the session
//!   thread with the disk writes pushed to the background thread.
//! * **batch sweep** — the fan-out measurement repeated on dedicated
//!   servers at `--batch-max` 1/32/256 (`fanout_batch_sweep`), pinning
//!   the coalescing win and the `batch_max = 1` per-line baseline.
//!
//! Results land in `BENCH_serve.json`, gated by `scripts/perf_gate.sh`
//! against `BENCH_serve_baseline.json` (throughput floors, latency and
//! stall ceilings). The queue bound is set far above the line volume, so
//! a single drop (a `lagged` notice) fails the bench — throughput
//! numbers must describe complete delivery.

#[path = "common/mod.rs"]
mod common;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

// Counting allocator (this bench binary only): counts every
// alloc/realloc so the encode hot path's allocs/op is exact.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[cfg(unix)]
fn main() {
    bench::run();
}

#[cfg(not(unix))]
fn main() {
    eprintln!("serve bench requires unix-domain sockets; skipped");
}

#[cfg(unix)]
mod bench {
    use super::{common, ALLOCS};
    use fitgpp::benchkit::{black_box, env_usize};
    use fitgpp::cluster::{ClusterSpec, NodeId};
    use fitgpp::job::{JobClass, JobId, TenantId};
    use fitgpp::sched::control::{JsonLineEncoder, SchedulerEvent};
    use fitgpp::sched::policy::PolicyKind;
    use fitgpp::serve::server::{self, ServeConfig};
    use fitgpp::serve::wire::ResponseEncoder;
    use fitgpp::sim::{JobRecord, SimConfig};
    use fitgpp::stats::sketch::QuantileSketch;
    use fitgpp::util::json::Json;
    use fitgpp::workload::source::WorkloadSource;
    use fitgpp::workload::Workload;
    use std::io::{BufRead, BufReader, Write};
    use std::os::unix::net::UnixStream;
    use std::path::PathBuf;
    use std::sync::atomic::Ordering;
    use std::sync::mpsc;
    use std::thread;
    use std::time::{Duration, Instant};

    const FANOUT_SUBSCRIBERS: usize = 4;
    const FANOUT_ID_BASE: u64 = 10_000_000;
    // Below FANOUT_ID_BASE so any latency-phase job still draining when
    // the fan-out subscribers attach is excluded by their id filter.
    const LAT_ID_BASE: u64 = 5_000_000;

    fn connect(sock: &PathBuf) -> (BufReader<UnixStream>, UnixStream) {
        let mut tries = 0;
        let stream = loop {
            match UnixStream::connect(sock) {
                Ok(s) => break s,
                Err(_) if tries < 500 => {
                    tries += 1;
                    thread::sleep(Duration::from_millis(10));
                }
                Err(e) => panic!("serve bench: socket never came up: {e}"),
            }
        };
        let reader = BufReader::new(stream.try_clone().expect("clone uds"));
        (reader, stream)
    }

    /// Read one line and panic if it is a `lagged` notice — a drop means
    /// the measurement no longer describes complete delivery.
    fn read_line(reader: &mut BufReader<UnixStream>, line: &mut String) -> Json {
        line.clear();
        assert!(reader.read_line(line).expect("read") > 0, "server closed early");
        let v = Json::parse(line).expect("json line");
        assert_ne!(v.get("type").as_str(), Some("lagged"), "bench dropped events: {line}");
        v
    }

    /// Representative events for the encode micro-measurement, including
    /// the widest line (`finished` with its full record).
    fn encode_sample_events() -> Vec<SchedulerEvent> {
        let record = JobRecord {
            id: JobId(421),
            class: JobClass::Be,
            demand: fitgpp::resources::ResourceVec::new(4.0, 16.0, 1.0),
            submit: 37,
            exec_time: 240,
            grace_period: 10,
            first_start: Some(40),
            finished_at: Some(301),
            preemptions: 2,
            evictions: 0,
            resched_intervals: vec![12],
            slowdown: 1.0987,
            cancelled: false,
            tenant: TenantId(3),
        };
        vec![
            SchedulerEvent::Submitted { at: 37, job: JobId(421), class: JobClass::Be },
            SchedulerEvent::Started { at: 40, job: JobId(421), node: NodeId(7) },
            SchedulerEvent::Preempted { at: 90, job: JobId(421) },
            SchedulerEvent::Resumed { at: 120, job: JobId(421), node: NodeId(3) },
            SchedulerEvent::Finished { at: 301, job: JobId(421), record },
        ]
    }

    /// The encode hot path in isolation, before any server thread exists
    /// (so the allocation counter sees this loop and nothing else).
    /// Returns `(encode_ns_per_op, steady_state_allocs_per_op)`; one op
    /// is one event line plus one ack response line.
    fn measure_encode() -> (f64, f64) {
        let events = encode_sample_events();
        let mut enc = JsonLineEncoder::new();
        let mut resp = ResponseEncoder::new();
        let mut i = 0usize;
        let mut sink = 0usize;
        let mut op = |i: usize| {
            let ev = &events[i % events.len()];
            black_box(enc.event(ev).len()) + black_box(resp.ack(Some(i as u64), i as u64).len())
        };
        for _ in 0..1_000 {
            sink = sink.wrapping_add(op(i));
            i += 1;
        }
        let iters = 200_000usize;
        let a0 = ALLOCS.load(Ordering::Relaxed);
        let t0 = Instant::now();
        for _ in 0..iters {
            sink = sink.wrapping_add(op(i));
            i += 1;
        }
        let elapsed = t0.elapsed();
        let allocs = ALLOCS.load(Ordering::Relaxed) - a0;
        black_box(sink);
        (
            elapsed.as_nanos() as f64 / iters as f64,
            allocs as f64 / iters as f64,
        )
    }

    /// Fan-out delivery rate against a dedicated server at the given
    /// `batch_max`: subscribers read until every job finishes, the
    /// driver pipelines the submits. Returns delivered lines/sec.
    fn fanout_rate(batch_max: usize, n_jobs: usize) -> f64 {
        let sock = std::env::temp_dir().join(format!(
            "fitgpp-serve-sweep-{}-{batch_max}.sock",
            std::process::id()
        ));
        let mut cfg = ServeConfig::new(SimConfig::new(ClusterSpec::tiny(4), PolicyKind::Fifo));
        cfg.uds = Some(sock.clone());
        cfg.queue_cap = 1 << 17;
        cfg.batch_max = batch_max;
        let server = thread::spawn(move || {
            let workload = Workload::new(Vec::new());
            let mut source = WorkloadSource::new(&workload);
            server::run(cfg, &mut source).expect("serve")
        });
        let (ready_tx, ready_rx) = mpsc::channel::<()>();
        let subs: Vec<_> = (0..FANOUT_SUBSCRIBERS)
            .map(|_| {
                let sock = sock.clone();
                let ready = ready_tx.clone();
                thread::spawn(move || {
                    let (mut reader, mut writer) = connect(&sock);
                    let mut line = String::new();
                    assert_eq!(
                        read_line(&mut reader, &mut line).get("type").as_str(),
                        Some("hello")
                    );
                    writeln!(writer, r#"{{"cmd":"subscribe","seq":1}}"#).expect("subscribe");
                    loop {
                        if read_line(&mut reader, &mut line).get("type").as_str() == Some("ack") {
                            break;
                        }
                    }
                    ready.send(()).expect("ready");
                    let mut lines = 0u64;
                    let mut finished = 0usize;
                    while finished < n_jobs {
                        let v = read_line(&mut reader, &mut line);
                        lines += 1;
                        if v.get("type").as_str() == Some("finished") {
                            finished += 1;
                        }
                    }
                    lines
                })
            })
            .collect();
        for _ in 0..FANOUT_SUBSCRIBERS {
            ready_rx.recv().expect("subscriber up");
        }
        let (mut reader, mut writer) = connect(&sock);
        let mut line = String::new();
        assert_eq!(read_line(&mut reader, &mut line).get("type").as_str(), Some("hello"));
        let t0 = Instant::now();
        for i in 0..n_jobs {
            writeln!(
                writer,
                r#"{{"cmd":"submit","id":{},"class":"BE","cpu":1,"ram_gb":1,"gpu":0,"exec_time":1,"seq":{i}}}"#,
                FANOUT_ID_BASE + i as u64
            )
            .expect("write submit");
        }
        let mut acked = 0usize;
        while acked < n_jobs {
            if read_line(&mut reader, &mut line).get("type").as_str() == Some("ack") {
                acked += 1;
            }
        }
        let mut delivered = 0u64;
        for s in subs {
            delivered += s.join().expect("subscriber");
        }
        let rate = delivered as f64 / t0.elapsed().as_secs_f64();
        writeln!(writer, r#"{{"cmd":"shutdown"}}"#).expect("shutdown");
        let outcome = server.join().expect("server thread");
        assert_eq!(outcome.stats.events_dropped, 0, "sweep must measure complete delivery");
        rate
    }

    pub fn run() {
        // --- encode hot path, measured before any other thread runs ----
        let (encode_ns_per_op, steady_state_allocs_per_op) = measure_encode();
        println!(
            "direct encode: {encode_ns_per_op:.0} ns/op, \
             {steady_state_allocs_per_op:.3} allocs/op (event + ack line)"
        );

        let sock = std::env::temp_dir()
            .join(format!("fitgpp-serve-bench-{}.sock", std::process::id()));
        let snap_dir = std::env::temp_dir()
            .join(format!("fitgpp-serve-bench-snaps-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&snap_dir);
        let mut cfg = ServeConfig::new(SimConfig::new(ClusterSpec::tiny(4), PolicyKind::Fifo));
        cfg.uds = Some(sock.clone());
        // Far above the total line volume: any overflow is a bench bug.
        cfg.queue_cap = 1 << 17;
        // Auto-snapshot throughout so snapshot_stall_ms measures a
        // realistic cadence with the disk writes in the background. The
        // whole bench spans ~100 virtual minutes on tiny(4), so every 10
        // minutes yields roughly ten snapshots.
        cfg.snapshot_dir = Some(snap_dir.clone());
        cfg.snapshot_every = 10;
        let server = thread::spawn(move || {
            let workload = Workload::new(Vec::new());
            let mut source = WorkloadSource::new(&workload);
            server::run(cfg, &mut source).expect("serve")
        });

        // --- commands/sec: pipelined submits, every ack read back -------
        let n_cmds = env_usize("FITGPP_SERVE_CMDS", 4000);
        let (mut reader, mut writer) = connect(&sock);
        let mut line = String::new();
        assert_eq!(read_line(&mut reader, &mut line).get("type").as_str(), Some("hello"));
        let t0 = Instant::now();
        for i in 0..n_cmds {
            writeln!(
                writer,
                r#"{{"cmd":"submit","id":{i},"class":"BE","cpu":1,"ram_gb":1,"gpu":0,"exec_time":1,"seq":{i}}}"#
            )
            .expect("write submit");
        }
        let mut acked = 0usize;
        while acked < n_cmds {
            if read_line(&mut reader, &mut line).get("type").as_str() == Some("ack") {
                acked += 1;
            }
        }
        let commands_per_sec = n_cmds as f64 / t0.elapsed().as_secs_f64();
        println!("commands/sec over uds: {commands_per_sec:.0} ({n_cmds} pipelined submits)");
        drop(writer);
        drop(reader);

        // --- ack latency: one closed-loop submit→ack at a time ----------
        let n_lat = env_usize("FITGPP_SERVE_LAT", 2000);
        let (mut reader, mut writer) = connect(&sock);
        assert_eq!(read_line(&mut reader, &mut line).get("type").as_str(), Some("hello"));
        let mut sketch = QuantileSketch::new();
        for i in 0..n_lat {
            let t = Instant::now();
            writeln!(
                writer,
                r#"{{"cmd":"submit","id":{},"class":"BE","cpu":1,"ram_gb":1,"gpu":0,"exec_time":1,"seq":{i}}}"#,
                LAT_ID_BASE + i as u64
            )
            .expect("write submit");
            loop {
                let v = read_line(&mut reader, &mut line);
                if v.get("type").as_str() == Some("ack")
                    && v.get("seq").as_u64() == Some(i as u64)
                {
                    break;
                }
            }
            sketch.insert(t.elapsed().as_secs_f64() * 1e6);
        }
        let ack_p50_us = sketch.quantile(0.5);
        let ack_p99_us = sketch.quantile(0.99);
        println!("ack latency: p50 {ack_p50_us:.0} µs, p99 {ack_p99_us:.0} µs ({n_lat} round trips)");
        drop(writer);
        drop(reader);

        // --- event fan-out: subscribers must see every job finish -------
        let n_jobs = env_usize("FITGPP_SERVE_JOBS", 4000);
        let (ready_tx, ready_rx) = mpsc::channel::<()>();
        let subs: Vec<_> = (0..FANOUT_SUBSCRIBERS)
            .map(|_| {
                let sock = sock.clone();
                let ready = ready_tx.clone();
                thread::spawn(move || {
                    let (mut reader, mut writer) = connect(&sock);
                    let mut line = String::new();
                    assert_eq!(
                        read_line(&mut reader, &mut line).get("type").as_str(),
                        Some("hello")
                    );
                    writeln!(writer, r#"{{"cmd":"subscribe","seq":1}}"#).expect("subscribe");
                    loop {
                        if read_line(&mut reader, &mut line).get("type").as_str() == Some("ack") {
                            break;
                        }
                    }
                    ready.send(()).expect("ready");
                    let mut lines = 0u64;
                    let mut finished = 0usize;
                    while finished < n_jobs {
                        let v = read_line(&mut reader, &mut line);
                        lines += 1;
                        if v.get("type").as_str() == Some("finished")
                            && v.get("job").as_u64().map_or(false, |j| j >= FANOUT_ID_BASE)
                        {
                            finished += 1;
                        }
                    }
                    lines
                })
            })
            .collect();
        for _ in 0..FANOUT_SUBSCRIBERS {
            ready_rx.recv().expect("subscriber up");
        }
        let (mut reader, mut writer) = connect(&sock);
        assert_eq!(read_line(&mut reader, &mut line).get("type").as_str(), Some("hello"));
        let t0 = Instant::now();
        for i in 0..n_jobs {
            writeln!(
                writer,
                r#"{{"cmd":"submit","id":{},"class":"BE","cpu":1,"ram_gb":1,"gpu":0,"exec_time":1,"seq":{i}}}"#,
                FANOUT_ID_BASE + i as u64
            )
            .expect("write submit");
        }
        let mut acked = 0usize;
        while acked < n_jobs {
            if read_line(&mut reader, &mut line).get("type").as_str() == Some("ack") {
                acked += 1;
            }
        }
        let mut delivered = 0u64;
        for s in subs {
            delivered += s.join().expect("subscriber");
        }
        let events_per_sec = delivered as f64 / t0.elapsed().as_secs_f64();
        println!(
            "event fan-out: {events_per_sec:.0} events/sec delivered \
             ({delivered} lines to {FANOUT_SUBSCRIBERS} subscribers, {n_jobs} jobs)"
        );

        writeln!(writer, r#"{{"cmd":"shutdown"}}"#).expect("shutdown");
        let outcome = server.join().expect("server thread");
        assert_eq!(
            outcome.stats.events_dropped, 0,
            "bench must measure complete delivery"
        );
        assert_eq!(
            outcome.result.metrics.completed as usize,
            n_cmds + n_lat + n_jobs
        );
        assert!(outcome.stats.snapshots > 0, "auto-snapshots never fired");
        let snapshot_stall_ms = outcome.stats.snapshot_stall_ms;
        println!(
            "snapshot stall: {snapshot_stall_ms:.1} ms on the session thread \
             across {} background snapshots",
            outcome.stats.snapshots
        );
        let _ = std::fs::remove_dir_all(&snap_dir);

        // --- fan-out batch sweep: per-line baseline vs coalescing -------
        let sweep_jobs = env_usize("FITGPP_SERVE_SWEEP_JOBS", 1500);
        let sweep: Vec<(usize, f64)> = [1usize, 32, 256]
            .iter()
            .map(|&b| (b, fanout_rate(b, sweep_jobs)))
            .collect();
        for (b, rate) in &sweep {
            println!("fan-out batch sweep: batch_max {b:>3} -> {rate:.0} events/sec");
        }

        let json = Json::obj(vec![
            ("bench", Json::str("serve")),
            ("commands_per_sec", Json::num(commands_per_sec)),
            ("events_per_sec", Json::num(events_per_sec)),
            ("subscribers", Json::num(FANOUT_SUBSCRIBERS as f64)),
            ("ack_p50_us", Json::num(ack_p50_us)),
            ("ack_p99_us", Json::num(ack_p99_us)),
            ("snapshot_stall_ms", Json::num(snapshot_stall_ms)),
            ("encode_ns_per_op", Json::num(encode_ns_per_op)),
            ("steady_state_allocs_per_op", Json::num(steady_state_allocs_per_op)),
            (
                "fanout_batch_sweep",
                Json::obj(vec![
                    ("batch_1", Json::num(sweep[0].1)),
                    ("batch_32", Json::num(sweep[1].1)),
                    ("batch_256", Json::num(sweep[2].1)),
                ]),
            ),
        ]);
        common::save_results_json("serve", &json);
    }
}
