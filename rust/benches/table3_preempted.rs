//! Table 3: proportion of preempted jobs when P = 1.
//! Paper: LRTP 9.6%, RAND 9.7%, FitGpp 6.3e-1% — FitGpp preempts an order
//! of magnitude fewer jobs because Eq. 2 picks a single sufficient victim
//! while the node-blind baselines scatter evictions.

#[path = "common/mod.rs"]
mod common;

use fitgpp::metrics::{preempted_table, PreemptionReport};
use fitgpp::sweep::extended_policies;

fn main() {
    let jobs = common::jobs_default();
    let seeds = common::seeds_default();
    println!("table3_preempted: {jobs} jobs x {seeds} seeds (P = 1)");

    // Every preempting policy in the suite: the paper's LRTP/RAND/FitGpp
    // row plus the SRTF and preempt-youngest trait ablations.
    let policies: Vec<_> = extended_policies()
        .into_iter()
        .filter(|p| p.preempts())
        .map(|p| (p.name(), p))
        .collect();
    let mut rows = Vec::new();
    let mut extra = String::new();
    for (name, policy) in &policies {
        let mut frac = 0.0;
        let mut signals = 0u64;
        for s in 0..seeds {
            let wl = common::paper_workload(100 + s as u64, jobs);
            let res = common::run_policy(&wl, *policy, s as u64);
            frac += res.preempted_fraction() / seeds as f64;
            signals += res.sched_stats.preemption_signals;
        }
        extra.push_str(&format!("{name}: {signals} preemption signals\n"));
        rows.push((
            name.as_str(),
            PreemptionReport { fraction_preempted: frac, hist: [0.0; 3] },
        ));
    }
    let mut out =
        preempted_table("Table 3: Proportion of preempted jobs (P = 1)", &rows).to_text();
    out.push('\n');
    out.push_str(&extra);
    common::save_results("table3_preempted", &out);
}
