//! Table 3: proportion of preempted jobs when P = 1.
//! Paper: LRTP 9.6%, RAND 9.7%, FitGpp 6.3e-1% — FitGpp preempts an order
//! of magnitude fewer jobs because Eq. 2 picks a single sufficient victim
//! while the node-blind baselines scatter evictions.

#[path = "common/mod.rs"]
mod common;

use fitgpp::metrics::{preempted_table, PreemptionReport};
use fitgpp::sched::policy::PolicyKind;

fn main() {
    let jobs = common::jobs_default();
    let seeds = common::seeds_default();
    println!("table3_preempted: {jobs} jobs x {seeds} seeds (P = 1)");

    let policies = [
        ("LRTP", PolicyKind::Lrtp),
        ("RAND", PolicyKind::Rand),
        ("FitGpp (s=4.0)", PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }),
    ];
    let mut rows = Vec::new();
    let mut extra = String::new();
    for (name, policy) in policies {
        let mut frac = 0.0;
        let mut signals = 0u64;
        for s in 0..seeds {
            let wl = common::paper_workload(100 + s as u64, jobs);
            let res = common::run_policy(&wl, policy, s as u64);
            frac += res.preempted_fraction() / seeds as f64;
            signals += res.sched_stats.preemption_signals;
        }
        extra.push_str(&format!("{name}: {} preemption signals\n", signals));
        rows.push((
            name,
            PreemptionReport { fraction_preempted: frac, hist: [0.0; 3] },
        ));
    }
    let mut out =
        preempted_table("Table 3: Proportion of preempted jobs (P = 1)", &rows).to_text();
    out.push('\n');
    out.push_str(&extra);
    common::save_results("table3_preempted", &out);
}
