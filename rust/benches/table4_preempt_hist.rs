//! Table 4: proportion of jobs preempted exactly 1 / 2 / ≥3 times when P
//! is infinite. Paper: FitGpp's whole histogram sits an order of magnitude
//! below LRTP/RAND's.

#[path = "common/mod.rs"]
mod common;

use fitgpp::metrics::{preempt_hist_table, PreemptionReport};
use fitgpp::sched::policy::PolicyKind;

fn main() {
    let jobs = common::jobs_default();
    let seeds = common::seeds_default();
    println!("table4_preempt_hist: {jobs} jobs x {seeds} seeds (P = inf)");

    let policies = [
        ("LRTP", PolicyKind::Lrtp),
        ("RAND", PolicyKind::Rand),
        ("FitGpp (s=4.0)", PolicyKind::FitGpp { s: 4.0, p_max: None }),
    ];
    let mut rows = Vec::new();
    for (name, policy) in policies {
        let mut hist = [0.0f64; 3];
        for s in 0..seeds {
            let wl = common::paper_workload(100 + s as u64, jobs);
            let h = common::run_policy(&wl, policy, s as u64).preemption_histogram();
            for i in 0..3 {
                hist[i] += h[i] / seeds as f64;
            }
        }
        rows.push((name, PreemptionReport { fraction_preempted: 0.0, hist }));
    }
    let out = preempt_hist_table(
        "Table 4: Proportion of jobs preempted N times (P = inf)",
        &rows,
    )
    .to_text();
    common::save_results("table4_preempt_hist", &out);
}
