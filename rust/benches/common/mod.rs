//! Shared helpers for the paper-reproduction benches.
//!
//! Scale: every bench defaults to a laptop-scale workload and honours
//! `FITGPP_JOBS` (job count) and `FITGPP_SEEDS` (workload repetitions, cf.
//! the paper's "eight sets of generated workloads") for full-paper runs:
//!
//! ```bash
//! FITGPP_JOBS=65536 FITGPP_SEEDS=8 cargo bench --bench table1_synthetic
//! ```

#![allow(dead_code)] // shared by all benches; each uses a subset

use fitgpp::benchkit::env_usize;
use fitgpp::cluster::ClusterSpec;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sim::{SimConfig, SimResult, Simulator};
use fitgpp::workload::synthetic::SyntheticWorkload;
use fitgpp::workload::Workload;
use std::io::Write as _;

pub fn jobs_default() -> usize {
    env_usize("FITGPP_JOBS", 8192)
}

pub fn seeds_default() -> usize {
    env_usize("FITGPP_SEEDS", 2)
}

pub fn cluster() -> ClusterSpec {
    ClusterSpec::pfn()
}

/// The §4.2 workload at bench scale.
pub fn paper_workload(seed: u64, jobs: usize) -> Workload {
    SyntheticWorkload::paper_section_4_2(seed)
        .with_cluster(cluster())
        .with_num_jobs(jobs)
        .generate()
}

/// The four §4.1 policies (FitGpp at the paper's headline setting).
pub fn paper_policies() -> Vec<(String, PolicyKind)> {
    vec![
        ("FIFO".into(), PolicyKind::Fifo),
        ("LRTP".into(), PolicyKind::Lrtp),
        ("RAND".into(), PolicyKind::Rand),
        ("FitGpp (s=4.0)".into(), PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }),
    ]
}

pub fn run_policy(wl: &Workload, policy: PolicyKind, seed: u64) -> SimResult {
    let mut cfg = SimConfig::new(cluster(), policy);
    cfg.seed = seed;
    Simulator::new(cfg).run(wl)
}

/// One-line sweep accounting every grid bench prints the same way.
pub fn report_sweep(res: &fitgpp::sweep::SweepResult) {
    eprintln!(
        "sweep: {} cells, {:.1}s wall on {} threads ({:.1}s serial-equivalent sim time)",
        res.cells.len(),
        res.wall.as_secs_f64(),
        res.threads,
        res.total_cell_wall().as_secs_f64()
    );
}

/// Write a machine-readable bench result as `BENCH_<name>.json` in the
/// repo root (cargo's working directory). Committed alongside the code, it
/// tracks the perf trajectory across PRs — each PR re-runs the bench and
/// refreshes the file.
pub fn save_results_json(name: &str, json: &fitgpp::util::json::Json) {
    let path = format!("BENCH_{name}.json");
    match std::fs::write(&path, json.to_pretty()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

/// Write a machine-readable copy of a bench's output next to the target
/// dir so EXPERIMENTS.md numbers are reproducible artifacts.
pub fn save_results(name: &str, content: &str) {
    let dir = std::path::Path::new("target/bench-results");
    let _ = std::fs::create_dir_all(dir);
    if let Ok(mut f) = std::fs::File::create(dir.join(format!("{name}.txt"))) {
        let _ = f.write_all(content.as_bytes());
    }
    println!("{content}");
}
