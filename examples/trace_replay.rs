//! §4.4: replay a cluster trace (the synthesized institution trace or any
//! CSV in the documented format) under all four policies.
//!
//! ```bash
//! cargo run --release --example trace_replay -- --jobs 8192
//! cargo run --release --example trace_replay -- --trace mycluster.csv
//! ```

use fitgpp::job::JobClass;
use fitgpp::metrics::{slowdown_table, Percentiles, SlowdownReport};
use fitgpp::prelude::*;
use fitgpp::util::cli::Cli;
use fitgpp::workload::trace::Trace;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("trace_replay", "replay a cluster trace under the four policies")
        .opt("trace", None, "CSV trace path (default: synthesize the institution trace)")
        .opt("jobs", Some("8192"), "jobs to synthesize when no --trace given")
        .opt("seed", Some("7"), "synthesis seed")
        .opt("save", None, "also write the used trace to this CSV path");
    let args = cli.parse();

    let wl = match args.get("trace") {
        Some(path) => {
            println!("replaying {path}");
            Trace::read_csv(Path::new(path))?
        }
        None => {
            let jobs = args.get_usize("jobs", 8192);
            println!("synthesizing the institution trace ({jobs} jobs) — see DESIGN.md §3");
            Trace::synthesize_institution(args.get_u64("seed", 7), jobs)
        }
    };
    if let Some(save) = args.get("save") {
        Trace::write_csv(&wl, Path::new(save))?;
        println!("trace written to {save}");
    }
    println!(
        "trace: {} jobs, {:.1}% TE, spanning {:.1} days\n",
        wl.len(),
        wl.te_fraction() * 100.0,
        wl.submit_span() as f64 / 1440.0
    );

    let cluster = ClusterSpec::pfn();
    let mut rows = Vec::new();
    for p in [
        PolicyKind::Fifo,
        PolicyKind::Lrtp,
        PolicyKind::Rand,
        PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
    ] {
        let mut cfg = SimConfig::new(cluster.clone(), p);
        cfg.seed = 3;
        let res = Simulator::new(cfg).run(&wl);
        rows.push((
            p.name(),
            SlowdownReport {
                te: Percentiles::of(&res.slowdowns(JobClass::Te)),
                be: Percentiles::of(&res.slowdowns(JobClass::Be)),
            },
        ));
    }
    let named: Vec<(&str, _)> = rows.iter().map(|(n, r)| (n.as_str(), *r)).collect();
    println!(
        "{}",
        slowdown_table("Percentiles of slowdown rates (trace replay, cf. Table 5)", &named).to_text()
    );
    Ok(())
}
