//! Quickstart: simulate the paper's §4.2 experiment at laptop scale and
//! print the Table-1 layout.
//!
//! ```bash
//! cargo run --release --example quickstart -- --jobs 4096
//! ```

use fitgpp::metrics::slowdown_table;
use fitgpp::prelude::*;
use fitgpp::util::cli::Cli;

fn main() {
    let cli = Cli::new("quickstart", "four-policy comparison on a synthetic workload")
        .opt("jobs", Some("4096"), "number of jobs")
        .opt("nodes", Some("84"), "cluster nodes")
        .opt("seed", Some("7"), "workload seed");
    let args = cli.parse();
    let jobs = args.get_usize("jobs", 4096);
    let nodes = args.get_usize("nodes", 84);
    let seed = args.get_u64("seed", 7);

    // 1. A cluster like the paper's: nodes of 32 CPUs / 256 GB / 8 GPUs.
    let cluster = ClusterSpec::homogeneous(nodes, fitgpp::resources::ResourceVec::pfn_node());

    // 2. The §4.2 synthetic workload: per-class truncated normals,
    //    submissions calibrated to keep the FIFO cluster load at 2.0.
    let wl = SyntheticWorkload::paper_section_4_2(seed)
        .with_cluster(cluster.clone())
        .with_num_jobs(jobs)
        .generate();
    println!(
        "workload: {} jobs ({:.1}% TE) submitted over {} simulated minutes\n",
        wl.len(),
        wl.te_fraction() * 100.0,
        wl.submit_span()
    );

    // 3. Run all four §4.1 policies on the identical workload.
    let policies = [
        PolicyKind::Fifo,
        PolicyKind::Lrtp,
        PolicyKind::Rand,
        PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
    ];
    let mut rows = Vec::new();
    for p in policies {
        let mut cfg = SimConfig::new(cluster.clone(), p);
        cfg.seed = 1;
        let res = Simulator::new(cfg).run(&wl);
        println!(
            "{:16} makespan {:5} min, {:4} preemption signals, {:5.2}% jobs preempted",
            p.name(),
            res.makespan,
            res.sched_stats.preemption_signals,
            res.preempted_fraction() * 100.0
        );
        rows.push((p.name(), res.slowdown_report()));
    }
    let named: Vec<(&str, _)> = rows.iter().map(|(n, r)| (n.as_str(), *r)).collect();
    println!("\n{}", slowdown_table("Percentiles of slowdown rates", &named).to_text());
}
