//! Scratch diagnostic (full-scale shape check). Not part of the public API.
use fitgpp::cluster::ClusterSpec;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sim::{SimConfig, Simulator};
use fitgpp::workload::synthetic::SyntheticWorkload;

fn main() {
    let jobs: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(8192);
    let cluster = ClusterSpec::pfn();
    let wl = SyntheticWorkload::paper_section_4_2(7)
        .with_cluster(cluster.clone())
        .with_num_jobs(jobs)
        .generate();
    eprintln!("workload: {} jobs, span {} min", wl.len(), wl.submit_span());
    for p in [PolicyKind::Fifo, PolicyKind::Lrtp, PolicyKind::Rand,
              PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }] {
        let t0 = std::time::Instant::now();
        let mut cfg = SimConfig::new(cluster.clone(), p);
        cfg.seed = 1;
        let r = Simulator::new(cfg).run(&wl);
        let sd = r.slowdown_report();
        let iv = r.intervals_report();
        println!("{:20} te(p50 {:6.2} p95 {:7.2}) be(p50 {:6.2} p95 {:7.2}) preempted {:.3}% signals {} replans {} interval(p50 {:.1} p95 {:.1}) makespan {} [{:.1}s]",
            p.name(), sd.te.p50, sd.te.p95, sd.be.p50, sd.be.p95,
            r.preempted_fraction()*100.0, r.sched_stats.preemption_signals,
            r.sched_stats.replans, iv.p50, iv.p95, r.makespan, t0.elapsed().as_secs_f64());
    }
}
