//! Workload explorer: inspect the §4.2 synthetic generator and the
//! institution-trace synthesizer — distribution summaries, load curves,
//! and CSV export. Useful for calibrating custom workloads before a
//! simulation campaign.
//!
//! ```bash
//! cargo run --release --example workload_explorer -- --jobs 8192 --institution
//! ```

use fitgpp::job::JobClass;
use fitgpp::prelude::*;
use fitgpp::stats::summary::Summary;
use fitgpp::util::cli::Cli;
use fitgpp::util::table::Table;
use fitgpp::workload::trace::Trace;

fn summarize(wl: &Workload) {
    let mut t = Table::new(
        "per-class distribution summary",
        &["class", "metric", "mean", "p50", "p95", "max"],
    );
    for class in [JobClass::Te, JobClass::Be] {
        let sel: Vec<&fitgpp::job::JobSpec> = wl.of_class(class).collect();
        if sel.is_empty() {
            continue;
        }
        let metrics: [(&str, Vec<f64>); 5] = [
            ("exec [min]", sel.iter().map(|j| j.exec_time as f64).collect()),
            ("grace [min]", sel.iter().map(|j| j.grace_period as f64).collect()),
            ("cpu", sel.iter().map(|j| j.demand.cpu).collect()),
            ("ram [GB]", sel.iter().map(|j| j.demand.ram_gb).collect()),
            ("gpu", sel.iter().map(|j| j.demand.gpu).collect()),
        ];
        for (name, xs) in metrics {
            let s = Summary::of(&xs);
            t.row(vec![
                class.as_str().into(),
                name.into(),
                format!("{:.1}", s.mean),
                format!("{:.1}", s.p50),
                format!("{:.1}", s.p95),
                format!("{:.1}", s.max),
            ]);
        }
    }
    println!("{}", t.to_text());
}

fn arrival_histogram(wl: &Workload, buckets: usize) {
    let span = wl.submit_span().max(1);
    let mut counts = vec![0usize; buckets];
    for j in &wl.jobs {
        let b = ((j.submit as f64 / span as f64) * (buckets - 1) as f64) as usize;
        counts[b] += 1;
    }
    let max = *counts.iter().max().unwrap_or(&1);
    println!("arrival-rate profile ({} buckets over {} min):", buckets, span);
    for (i, c) in counts.iter().enumerate() {
        let bar = "#".repeat((c * 50 / max.max(1)).max(usize::from(*c > 0)));
        println!("  {:3} | {bar} {c}", i);
    }
}

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("workload_explorer", "inspect generated workloads")
        .opt("jobs", Some("8192"), "number of jobs")
        .opt("seed", Some("7"), "seed")
        .opt("gp-scale", Some("1.0"), "grace-period scale")
        .opt("te-fraction", Some("0.3"), "TE fraction (synthetic mode)")
        .opt("export", None, "write the workload as CSV to this path")
        .flag("institution", "explore the §4.4 institution trace instead of §4.2");
    let args = cli.parse();
    let jobs = args.get_usize("jobs", 8192);
    let seed = args.get_u64("seed", 7);

    let wl = if args.has("institution") {
        println!("institution trace (synthesized; heavy-tailed, diurnal, bursty)\n");
        Trace::synthesize_institution(seed, jobs)
    } else {
        println!("§4.2 synthetic workload (FIFO load calibrated to 2.0)\n");
        SyntheticWorkload::paper_section_4_2(seed)
            .with_num_jobs(jobs)
            .with_te_fraction(args.get_f64("te-fraction", 0.3))
            .with_gp_scale(args.get_f64("gp-scale", 1.0))
            .generate()
    };

    println!(
        "{} jobs | {:.1}% TE | submission span {} min ({:.1} days)\n",
        wl.len(),
        wl.te_fraction() * 100.0,
        wl.submit_span(),
        wl.submit_span() as f64 / 1440.0
    );
    summarize(&wl);
    arrival_histogram(&wl, 24);

    let total = wl.total_work();
    let cap = ClusterSpec::pfn().total_capacity();
    println!(
        "\ntotal work: {:.0} CPU-min, {:.0} GB-min, {:.0} GPU-min",
        total.cpu, total.ram_gb, total.gpu
    );
    println!(
        "ideal (work-conserving) makespan on the 84-node cluster: {:.0} min",
        total.dominant_share(&cap)
    );

    if let Some(path) = args.get("export") {
        Trace::write_csv(&wl, std::path::Path::new(path))?;
        println!("exported to {path}");
    }
    Ok(())
}
