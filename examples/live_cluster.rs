//! **End-to-end driver**: the full three-layer system on a real workload.
//!
//! The L3 scheduler (FitGpp) coordinates a mini-cluster whose jobs are
//! *actual transformer training runs*: each running job executes the
//! AOT-compiled JAX train step (with its Pallas attention/layernorm
//! kernels) through the PJRT CPU client, logging a real loss curve. A
//! preemption's grace period performs real suspension work — serializing
//! the model parameters to a checkpoint — and the victim later resumes
//! from that checkpoint with its progress intact.
//!
//! Requires `make artifacts`. Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! cargo run --release --example live_cluster -- --policy fitgpp:s=4,p=1 --jobs 10
//! ```

use fitgpp::live::{demo_workload, LiveCluster, LiveConfig, LiveEvent};
use fitgpp::sched::policy::PolicyKind;
use fitgpp::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("live_cluster", "run real PJRT training jobs under the scheduler")
        .opt("policy", Some("fitgpp:s=4,p=1"), "scheduling policy")
        .opt("jobs", Some("10"), "number of training jobs")
        .opt("tick-ms", Some("150"), "wall milliseconds per simulated minute")
        .opt("seed", Some("7"), "seed")
        .opt("json-out", None, "write the live report JSON here");
    let args = cli.parse();
    let policy = PolicyKind::parse(args.get_or("policy", "fitgpp:s=4,p=1"))
        .ok_or_else(|| anyhow::anyhow!("bad --policy"))?;

    let mut cfg = LiveConfig::demo(policy);
    cfg.tick_ms = args.get_u64("tick-ms", 150);
    cfg.seed = args.get_u64("seed", 7);
    let wl = demo_workload(args.get_usize("jobs", 10), cfg.seed);
    println!(
        "live cluster: {} nodes x {}, policy {}, {} jobs ({:.0}% TE), {} ms/min",
        cfg.cluster.nodes.len(),
        cfg.cluster.nodes[0],
        policy.name(),
        wl.len(),
        wl.te_fraction() * 100.0,
        cfg.tick_ms
    );

    let cluster = LiveCluster::new(cfg)?;
    let report = cluster.run(&wl)?;

    println!(
        "\ncompleted: {} scheduled minutes in {:.1}s wall, {} real train steps",
        report.ticks,
        report.wall.as_secs_f64(),
        report.total_steps
    );
    println!("\nper-job outcomes:");
    for r in &report.records {
        let drop = report.loss_drop(r.id);
        println!(
            "  {:7} [{}] slowdown {:5.2}  preemptions {}  loss {}",
            r.id.to_string(),
            r.class.as_str(),
            r.slowdown,
            r.preemptions,
            match drop {
                Some((a, b)) => format!("{a:.3} → {b:.3}"),
                None => "n/a (few samples)".to_string(),
            }
        );
    }
    println!("\nsuspension events (real checkpoint work during grace periods):");
    for e in &report.events {
        if let LiveEvent::Suspended { job, at_step, checkpoint_ms, checkpoint_bytes } = e {
            println!(
                "  {job} checkpointed at step {at_step}: {checkpoint_bytes} bytes in {checkpoint_ms:.1} ms"
            );
        }
    }
    let resumed: Vec<String> = report
        .events
        .iter()
        .filter_map(|e| match e {
            LiveEvent::Spawned { job, resumed_at_step, .. } if *resumed_at_step > 0 => {
                Some(format!("{job}@step{resumed_at_step}"))
            }
            _ => None,
        })
        .collect();
    if !resumed.is_empty() {
        println!("resumed from checkpoint: {}", resumed.join(", "));
    }

    if let Some(p) = args.get("json-out") {
        std::fs::write(p, report.to_json().to_pretty())?;
        println!("report written to {p}");
    }
    Ok(())
}
