//! Sensitivity sweeps (Figs. 4–7 in one driver): vary FitGpp's `s`, the
//! preemption cap `P`, the TE-job proportion, and the grace-period scale,
//! writing one CSV per sweep for plotting.
//!
//! ```bash
//! cargo run --release --example synthetic_sweep -- --jobs 4096 --out-dir sweeps
//! ```

use fitgpp::job::JobClass;
use fitgpp::prelude::*;
use fitgpp::stats::summary::percentile;
use fitgpp::util::cli::Cli;
use fitgpp::util::table::Table;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("synthetic_sweep", "Figs. 4-7 sensitivity sweeps")
        .opt("jobs", Some("4096"), "jobs per configuration")
        .opt("out-dir", Some("sweeps"), "directory for CSV outputs")
        .opt("seed", Some("7"), "workload seed");
    let args = cli.parse();
    let jobs = args.get_usize("jobs", 4096);
    let seed = args.get_u64("seed", 7);
    let out_dir = args.get_string("out-dir", "sweeps");
    std::fs::create_dir_all(&out_dir)?;
    let cluster = ClusterSpec::pfn();

    let base_wl = || {
        SyntheticWorkload::paper_section_4_2(seed)
            .with_cluster(cluster.clone())
            .with_num_jobs(jobs)
    };
    let run = |wl: &Workload, p: PolicyKind| {
        let mut cfg = SimConfig::new(cluster.clone(), p);
        cfg.seed = 1;
        Simulator::new(cfg).run(wl)
    };

    // -- Fig. 4: s sweep ---------------------------------------------------
    let wl = base_wl().generate();
    let mut t = Table::new("fig4: s sweep", &["s", "te_p50", "te_p95", "te_p99", "be_p50", "be_p95", "be_p99"]);
    for s in [0.0, 0.5, 1.0, 2.0, 4.0, 8.0] {
        let r = run(&wl, PolicyKind::FitGpp { s, p_max: Some(1) }).slowdown_report();
        t.row(vec![
            s.to_string(),
            format!("{:.3}", r.te.p50), format!("{:.3}", r.te.p95), format!("{:.3}", r.te.p99),
            format!("{:.3}", r.be.p50), format!("{:.3}", r.be.p95), format!("{:.3}", r.be.p99),
        ]);
    }
    println!("{}", t.to_text());
    std::fs::write(Path::new(&out_dir).join("fig4_s.csv"), t.to_csv())?;

    // -- Fig. 5: P sweep -----------------------------------------------------
    let mut t = Table::new("fig5: P sweep", &["P", "te_p95", "be_p95"]);
    for p in [Some(1), Some(2), Some(4), None] {
        let r = run(&wl, PolicyKind::FitGpp { s: 4.0, p_max: p }).slowdown_report();
        t.row(vec![
            p.map(|x| x.to_string()).unwrap_or("inf".into()),
            format!("{:.3}", r.te.p95),
            format!("{:.3}", r.be.p95),
        ]);
    }
    println!("{}", t.to_text());
    std::fs::write(Path::new(&out_dir).join("fig5_p.csv"), t.to_csv())?;

    // -- Fig. 6: TE-ratio sweep ----------------------------------------------
    let mut t = Table::new("fig6: TE-ratio sweep", &["te_frac", "policy", "te_p95", "be_p95"]);
    for frac in [0.1, 0.3, 0.5, 0.7] {
        let wl = base_wl().with_te_fraction(frac).generate();
        for p in [PolicyKind::Fifo, PolicyKind::Lrtp, PolicyKind::Rand, PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }] {
            let res = run(&wl, p);
            t.row(vec![
                frac.to_string(),
                p.name(),
                format!("{:.2}", percentile(&res.slowdowns(JobClass::Te), 95.0)),
                format!("{:.2}", percentile(&res.slowdowns(JobClass::Be), 95.0)),
            ]);
        }
    }
    println!("{}", t.to_text());
    std::fs::write(Path::new(&out_dir).join("fig6_te_ratio.csv"), t.to_csv())?;

    // -- Fig. 7: GP-scale sweep -----------------------------------------------
    let mut t = Table::new("fig7: GP-scale sweep", &["gp_scale", "policy", "te_p95", "be_p95"]);
    for scale in [1.0, 2.0, 4.0, 8.0] {
        let wl = base_wl().with_gp_scale(scale).generate();
        for (label, p) in [
            ("LRTP", PolicyKind::Lrtp),
            ("RAND", PolicyKind::Rand),
            ("FitGpp s=4", PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }),
            ("FitGpp s=8", PolicyKind::FitGpp { s: 8.0, p_max: Some(1) }),
        ] {
            let res = run(&wl, p);
            t.row(vec![
                scale.to_string(),
                label.to_string(),
                format!("{:.2}", percentile(&res.slowdowns(JobClass::Te), 95.0)),
                format!("{:.2}", percentile(&res.slowdowns(JobClass::Be), 95.0)),
            ]);
        }
    }
    println!("{}", t.to_text());
    std::fs::write(Path::new(&out_dir).join("fig7_gp_scale.csv"), t.to_csv())?;

    println!("CSV series written to {out_dir}/");
    Ok(())
}
