//! Sensitivity sweeps (Figs. 4–7 in one driver): vary FitGpp's `s`, the
//! preemption cap `P`, the TE-job proportion, and the grace-period scale,
//! writing one CSV per sweep for plotting.
//!
//! Each figure is one [`SweepSpec`] grid run on all cores by the
//! work-stealing sweep harness; workloads are generated once per
//! coordinate and shared across policies.
//!
//! ```bash
//! cargo run --release --example synthetic_sweep -- --jobs 4096 --out-dir sweeps
//! ```

use fitgpp::job::JobClass;
use fitgpp::prelude::*;
use fitgpp::sweep::paper_policies;
use fitgpp::util::cli::Cli;
use fitgpp::util::table::Table;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("synthetic_sweep", "Figs. 4-7 sensitivity sweeps")
        .opt("jobs", Some("4096"), "jobs per configuration")
        .opt("out-dir", Some("sweeps"), "directory for CSV outputs")
        .opt("seed", Some("7"), "workload seed")
        .opt("threads", Some("0"), "worker threads (0 = all cores)");
    let args = cli.parse();
    let jobs = args.get_usize("jobs", 4096);
    let seed = args.get_u64("seed", 7);
    let threads = args.get_usize("threads", 0);
    let out_dir = args.get_string("out-dir", "sweeps");
    std::fs::create_dir_all(&out_dir)?;
    let cluster = ClusterSpec::pfn();

    let base = |policies: Vec<PolicyKind>| {
        SweepSpec::new(cluster.clone(), policies)
            .with_num_jobs(jobs)
            .with_seeds(vec![seed])
            .with_threads(threads)
    };

    // -- Fig. 4: s sweep ---------------------------------------------------
    let s_grid = [0.0, 0.5, 1.0, 2.0, 4.0, 8.0];
    let res = base(Vec::new()).fitgpp_s_grid(&s_grid, Some(1)).run();
    let mut t = Table::new(
        "fig4: s sweep",
        &["s", "te_p50", "te_p95", "te_p99", "be_p50", "be_p95", "be_p99"],
    );
    for &s in &s_grid {
        let p = PolicyKind::FitGpp { s, p_max: Some(1) };
        let te = res.pooled_percentiles(p, JobClass::Te);
        let be = res.pooled_percentiles(p, JobClass::Be);
        t.row(vec![
            s.to_string(),
            format!("{:.3}", te.p50), format!("{:.3}", te.p95), format!("{:.3}", te.p99),
            format!("{:.3}", be.p50), format!("{:.3}", be.p95), format!("{:.3}", be.p99),
        ]);
    }
    println!("{}", t.to_text());
    std::fs::write(Path::new(&out_dir).join("fig4_s.csv"), t.to_csv())?;

    // -- Fig. 5: P sweep -----------------------------------------------------
    let p_grid = [Some(1), Some(2), Some(4), None];
    let res = base(Vec::new()).fitgpp_p_grid(4.0, &p_grid).run();
    let mut t = Table::new("fig5: P sweep", &["P", "te_p95", "be_p95"]);
    for &p_max in &p_grid {
        let p = PolicyKind::FitGpp { s: 4.0, p_max };
        t.row(vec![
            p_max.map(|x| x.to_string()).unwrap_or("inf".into()),
            format!("{:.3}", res.pooled_percentiles(p, JobClass::Te).p95),
            format!("{:.3}", res.pooled_percentiles(p, JobClass::Be).p95),
        ]);
    }
    println!("{}", t.to_text());
    std::fs::write(Path::new(&out_dir).join("fig5_p.csv"), t.to_csv())?;

    // -- Fig. 6: TE-ratio sweep ----------------------------------------------
    let ratios = vec![0.1, 0.3, 0.5, 0.7];
    let res = base(paper_policies()).with_te_ratios(ratios.clone()).run();
    let mut t = Table::new(
        "fig6: TE-ratio sweep",
        &["te_frac", "policy", "te_p95", "be_p95"],
    );
    for &frac in &ratios {
        for p in paper_policies() {
            let te = res.pooled_percentiles_where(|c| c.policy == p && c.te_ratio == frac, JobClass::Te);
            let be = res.pooled_percentiles_where(|c| c.policy == p && c.te_ratio == frac, JobClass::Be);
            t.row(vec![
                frac.to_string(),
                p.name(),
                format!("{:.2}", te.p95),
                format!("{:.2}", be.p95),
            ]);
        }
    }
    println!("{}", t.to_text());
    std::fs::write(Path::new(&out_dir).join("fig6_te_ratio.csv"), t.to_csv())?;

    // -- Fig. 7: GP-scale sweep -----------------------------------------------
    let scales = vec![1.0, 2.0, 4.0, 8.0];
    let fig7_policies = vec![
        PolicyKind::Lrtp,
        PolicyKind::Rand,
        PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
        PolicyKind::FitGpp { s: 8.0, p_max: Some(1) },
    ];
    let res = base(fig7_policies.clone()).with_gp_scales(scales.clone()).run();
    let mut t = Table::new(
        "fig7: GP-scale sweep",
        &["gp_scale", "policy", "te_p95", "be_p95"],
    );
    for &scale in &scales {
        for p in &fig7_policies {
            let te = res.pooled_percentiles_where(|c| c.policy == *p && c.gp_scale == scale, JobClass::Te);
            let be = res.pooled_percentiles_where(|c| c.policy == *p && c.gp_scale == scale, JobClass::Be);
            t.row(vec![
                scale.to_string(),
                p.name(),
                format!("{:.2}", te.p95),
                format!("{:.2}", be.p95),
            ]);
        }
    }
    println!("{}", t.to_text());
    std::fs::write(Path::new(&out_dir).join("fig7_gp_scale.csv"), t.to_csv())?;

    println!("CSV series written to {out_dir}/");
    Ok(())
}
