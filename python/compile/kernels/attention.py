"""Layer 1: fused causal attention as a Pallas kernel — forward *and*
backward (pallas_call has no built-in transpose rule, and a hand-written
backward kernel is the production idiom anyway, cf. FlashAttention).

The paper's live jobs are transformer training steps; attention is their
compute hot-spot, so it is written as a Pallas kernel pair and called from
the L2 model (it therefore lowers into the same HLO artifact the rust
runtime executes, inside the fused fwd+bwd train step).

TPU-idiomatic structure (DESIGN.md §Hardware-Adaptation):

* Grid over attention heads: ``grid = (H,)``. Each program instance owns
  one head's full ``[S, D]`` Q/K/V tiles — for the model sizes shipped
  here (S ≤ 256, D ≤ 64) a head's working set is ≤ ~1 MiB, far under the
  ~16 MiB VMEM budget, so no inner K/V streaming loop is needed; the
  BlockSpec index map *is* the HBM→VMEM schedule.
* Matmuls accumulate in f32 via ``preferred_element_type`` — the MXU
  pattern (bf16 in, f32 accumulate).
* The causal mask is built with ``broadcasted_iota`` (2-D iota — TPU
  requires ≥2-D) rather than materialized from HBM.
* The backward kernel **recomputes** the probability matrix from Q/K
  instead of saving it (FlashAttention-style rematerialization): residuals
  are just Q, K, V — O(S·D) instead of O(S²) HBM traffic.

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT client cannot execute. The kernel
*structure* (grid/BlockSpec/accumulation dtypes) is what carries to real
hardware; see DESIGN.md §Perf for the VMEM/MXU accounting.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = float(jnp.finfo(jnp.float32).min)


def _probs(q, k, scale):
    """Masked softmax(QKᵀ·scale) in f32 — shared by fwd and bwd kernels."""
    s = q.shape[0]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    logits = jnp.where(row >= col, logits, _NEG)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    """One head forward: softmax(mask(QKᵀ·scale))·V, f32 accumulation."""
    q, k, v = q_ref[...], k_ref[...], v_ref[...]
    p = _probs(q, k, scale)
    out = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = out.astype(o_ref.dtype)


def _bwd_kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref, *, scale):
    """One head backward, recomputing P from Q/K (no S×S residual):

    dV = Pᵀ·dO;  dP = dO·Vᵀ;  dS = P ∘ (dP − rowsum(dP ∘ P));
    dQ = dS·K·scale;  dK = dSᵀ·Q·scale.
    Masked entries have P = 0 ⇒ dS = 0 there automatically.
    """
    q, k, v, do = q_ref[...], k_ref[...], v_ref[...], do_ref[...]
    p = _probs(q, k, scale)  # [S, S] f32
    dof = do.astype(jnp.float32)
    dv = jax.lax.dot_general(
        p, dof, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # Pᵀ·dO : [S, D]
    dp = jax.lax.dot_general(
        dof, v.astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # dO·Vᵀ : [S, S]
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jax.lax.dot_general(
        ds, k.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale
    dk = jax.lax.dot_general(
        ds, q.astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale
    dq_ref[...] = dq.astype(dq_ref.dtype)
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _head_block(s, d):
    return pl.BlockSpec((None, s, d), lambda i: (i, 0, 0))


def _fwd_call(q, k, v, scale, interpret):
    h, s, d = q.shape
    blk = _head_block(s, d)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale),
        grid=(h,),
        in_specs=[blk, blk, blk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((h, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def _bwd_call(q, k, v, do, scale, interpret):
    h, s, d = q.shape
    blk = _head_block(s, d)
    shape = jax.ShapeDtypeStruct((h, s, d), q.dtype)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, scale=scale),
        grid=(h,),
        in_specs=[blk, blk, blk, blk],
        out_specs=[blk, blk, blk],
        out_shape=[shape, shape, shape],
        interpret=interpret,
    )(q, k, v, do)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _attention(q, k, v, scale, interpret):
    return _fwd_call(q, k, v, scale, interpret)


def _attention_fwd(q, k, v, scale, interpret):
    return _fwd_call(q, k, v, scale, interpret), (q, k, v)


def _attention_bwd(scale, interpret, res, do):
    q, k, v = res
    return _bwd_call(q, k, v, do, scale, interpret)


_attention.defvjp(_attention_fwd, _attention_bwd)


def causal_attention(q, k, v, scale=None, interpret=True):
    """Fused causal attention over ``[H, S, D]`` tensors; differentiable
    via the backward Pallas kernel. Matches ``ref.causal_attention``
    numerically (pytest enforces both directions)."""
    _, _, d = q.shape
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    return _attention(q, k, v, scale, interpret)


def vmem_bytes(s, d, dtype_bytes=4, backward=False):
    """Estimated VMEM working set per program instance (DESIGN.md §Perf):
    Q/K/V/O (+dO, dQ, dK, dV for backward) tiles plus the f32 S×S
    scratch (P, and dP/dS for backward)."""
    tiles = (8 if backward else 4) * s * d * dtype_bytes
    scratch = (3 if backward else 2) * s * s * 4
    return tiles + scratch
