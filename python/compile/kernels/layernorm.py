"""Layer 1: fused LayerNorm as a Pallas kernel pair (forward + input
backward).

Grid over row-blocks: each program instance normalizes a ``[BLOCK, D]``
tile in VMEM (mean/variance/scale/shift fused in one pass). Statistics
are computed in f32 regardless of input dtype.

Backward: the input gradient is row-local, so it is another Pallas kernel
over the same row-block grid (recomputing μ/σ, FlashAttention-style);
the γ/β gradients are cross-row reductions and are left to XLA (a single
fused reduce — no benefit from a hand kernel).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)  # [BLOCK, D]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _bwd_kernel(x_ref, g_ref, dy_ref, dx_ref, *, eps):
    """dx for y = x̂·γ + β with x̂ = (x−μ)/σ:
    dx = (dŷ − mean(dŷ) − x̂·mean(dŷ∘x̂)) / σ, where dŷ = dy·γ."""
    x = x_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = (x - mu) * rstd
    dyh = dy * g
    dx = (
        dyh
        - jnp.mean(dyh, axis=-1, keepdims=True)
        - xhat * jnp.mean(dyh * xhat, axis=-1, keepdims=True)
    ) * rstd
    dx_ref[...] = dx.astype(dx_ref.dtype)


def _pick_block(rows, block_rows):
    return block_rows if rows % block_rows == 0 else rows


def _fwd_call(x, g, b, eps, block_rows, interpret):
    rows, dim = x.shape
    blk = _pick_block(rows, block_rows)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, eps=eps),
        grid=(rows // blk,),
        in_specs=[
            pl.BlockSpec((blk, dim), lambda i: (i, 0)),
            pl.BlockSpec((dim,), lambda i: (0,)),
            pl.BlockSpec((dim,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, dim), x.dtype),
        interpret=interpret,
    )(x, g, b)


def _bwd_call(x, g, dy, eps, block_rows, interpret):
    rows, dim = x.shape
    blk = _pick_block(rows, block_rows)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, eps=eps),
        grid=(rows // blk,),
        in_specs=[
            pl.BlockSpec((blk, dim), lambda i: (i, 0)),
            pl.BlockSpec((dim,), lambda i: (0,)),
            pl.BlockSpec((blk, dim), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, dim), x.dtype),
        interpret=interpret,
    )(x, g, dy)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _layernorm(x, g, b, eps, block_rows, interpret):
    return _fwd_call(x, g, b, eps, block_rows, interpret)


def _layernorm_fwd(x, g, b, eps, block_rows, interpret):
    return _fwd_call(x, g, b, eps, block_rows, interpret), (x, g)


def _layernorm_bwd(eps, block_rows, interpret, res, dy):
    x, g = res
    dx = _bwd_call(x, g, dy, eps, block_rows, interpret)
    # γ/β grads: cross-row reductions, left to XLA (fused reduce).
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    xhat = (xf - mu) * jax.lax.rsqrt(var + eps)
    dyf = dy.astype(jnp.float32)
    dg = jnp.sum(dyf * xhat, axis=0).astype(g.dtype)
    db = jnp.sum(dyf, axis=0).astype(g.dtype)
    return dx, dg, db


_layernorm.defvjp(_layernorm_fwd, _layernorm_bwd)


def layernorm(x, gamma, beta, eps=1e-5, block_rows=128, interpret=True):
    """Fused layernorm over the last axis of ``[rows, dim]``;
    differentiable via the backward Pallas kernel. Row counts that do not
    divide ``block_rows`` fall back to one full-array tile."""
    return _layernorm(x, gamma, beta, eps, block_rows, interpret)
