"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest sweeps shapes/dtypes with
hypothesis and asserts the Pallas kernels (interpret=True) match these to
tight tolerances. They are also used directly by the L2 model under
``use_pallas=False`` for A/B testing the lowering.
"""

import jax.numpy as jnp


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def causal_attention(q, k, v, scale=None):
    """Reference causal scaled-dot-product attention.

    Args:
      q, k, v: ``[heads, seq, head_dim]`` arrays.
      scale: softmax temperature; defaults to ``1/sqrt(head_dim)``.

    Returns:
      ``[heads, seq, head_dim]``.
    """
    _, s, d = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    logits = jnp.einsum(
        "hqd,hkd->hqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    neg = jnp.asarray(jnp.finfo(jnp.float32).min, dtype=logits.dtype)
    logits = jnp.where(mask[None, :, :], logits, neg)
    probs = _softmax(logits)
    out = jnp.einsum(
        "hqk,hkd->hqd", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.astype(q.dtype)


def layernorm(x, gamma, beta, eps=1e-5):
    """Reference layer normalization over the last axis.

    Args:
      x: ``[rows, dim]``.
      gamma, beta: ``[dim]`` scale/shift.
    """
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) / jnp.sqrt(var + eps) * gamma.astype(jnp.float32) + beta.astype(
        jnp.float32
    )
    return y.astype(x.dtype)
