"""AOT lowering: JAX → HLO **text** artifacts + manifest for the rust
runtime. Runs once at build time (``make artifacts``); python is never on
the request path.

HLO text (not ``.serialize()``): jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids which the image's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Artifacts written to ``--out-dir`` (default ``artifacts/``):
  probe.hlo.txt              f(x,y) = (x·y + 2,)  — runtime smoke test
  train_step_<v>.hlo.txt     fused fwd+bwd+SGD per model variant
  manifest.json              calling convention for rust (see
                             rust/src/runtime/manifest.rs)
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_probe() -> str:
    """The runtime smoke-test function (same as the reference example)."""

    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def lower_train_step(cfg: M.ModelConfig) -> str:
    """Lower the fused train step with example shapes from the config."""
    params = [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _, shape in M.param_specs(cfg)
    ]
    tokens = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    import functools

    fn = functools.partial(M.train_step_flat, cfg)
    return to_hlo_text(jax.jit(fn).lower(*params, tokens))


def variant_manifest(cfg: M.ModelConfig, filename: str) -> dict:
    return {
        "name": cfg.name,
        "train_step": filename,
        "tokens": {
            "name": "tokens",
            "shape": [cfg.batch, cfg.seq],
            "dtype": "s32",
        },
        "params": [
            {"name": name, "shape": list(shape), "dtype": "f32"}
            for name, shape in M.param_specs(cfg)
        ],
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_head": cfg.n_head,
            "d_ff": cfg.d_ff,
            "n_layer": cfg.n_layer,
            "seq": cfg.seq,
            "batch": cfg.batch,
            "lr": cfg.lr,
            "param_count": M.param_count(cfg),
        },
    }


VARIANTS = {"tiny": M.TINY, "small": M.SMALL}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default="tiny,small",
        help="comma-separated subset of: " + ",".join(VARIANTS),
    )
    # Back-compat with the original Makefile single-file interface.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"models": []}

    probe = lower_probe()
    with open(os.path.join(out_dir, "probe.hlo.txt"), "w") as f:
        f.write(probe)
    manifest["probe"] = "probe.hlo.txt"
    print(f"probe.hlo.txt: {len(probe)} chars", file=sys.stderr)

    for name in args.variants.split(","):
        cfg = VARIANTS[name.strip()]
        filename = f"train_step_{cfg.name}.hlo.txt"
        text = lower_train_step(cfg)
        with open(os.path.join(out_dir, filename), "w") as f:
            f.write(text)
        manifest["models"].append(variant_manifest(cfg, filename))
        print(
            f"{filename}: {len(text)} chars "
            f"({M.param_count(cfg):,} params)",
            file=sys.stderr,
        )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"manifest.json → {out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
