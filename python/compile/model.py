"""Layer 2: decoder-only transformer LM — forward, loss, and SGD train
step — written in JAX, calling the L1 Pallas kernels.

This is the "DL job" the FitGpp scheduler schedules: ``aot.py`` lowers
``train_step`` once to HLO text; the rust runtime executes it on the
request path with python long gone.

Parameters travel as a **flat list of arrays** (the PJRT calling
convention has no pytrees); ``param_specs`` documents the order, and
``manifest.json`` carries it to rust.

Architecture (pre-LN GPT):
  tok_emb + pos_emb → [block × n_layer] → ln_f → logits (tied embedding)
  block: x + attn(ln1(x));  x + mlp(ln2(x));  mlp = gelu(x·W1)·W2
"""

import dataclasses
import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import attention as attn_k
from .kernels import layernorm as ln_k
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters of one model variant."""

    name: str = "tiny"
    vocab: int = 256
    d_model: int = 64
    n_head: int = 2
    d_ff: int = 256
    n_layer: int = 2
    seq: int = 64
    batch: int = 8
    lr: float = 0.05
    use_pallas: bool = True

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head


TINY = ModelConfig()
SMALL = ModelConfig(
    name="small", vocab=512, d_model=128, n_head=4, d_ff=512, n_layer=4,
    seq=128, batch=8, lr=0.03,
)


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Flat parameter order: (name, shape) pairs. Rust mirrors this via the
    manifest — do not reorder without bumping the manifest."""
    specs = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq, cfg.d_model)),
    ]
    for layer in range(cfg.n_layer):
        p = f"l{layer}."
        specs += [
            (p + "ln1.g", (cfg.d_model,)),
            (p + "ln1.b", (cfg.d_model,)),
            (p + "wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2.g", (cfg.d_model,)),
            (p + "ln2.b", (cfg.d_model,)),
            (p + "w1", (cfg.d_model, cfg.d_ff)),
            (p + "w2", (cfg.d_ff, cfg.d_model)),
        ]
    specs += [("ln_f.g", (cfg.d_model,)), ("ln_f.b", (cfg.d_model,))]
    return specs


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_specs(cfg))


def init_params(cfg: ModelConfig, key) -> List[jax.Array]:
    """GPT-style init: N(0, 0.02) for weights, ones/zeros for LN."""
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".g"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(".b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            params.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
    return params


def _layernorm(cfg, x2d, g, b):
    if cfg.use_pallas:
        return ln_k.layernorm(x2d, g, b)
    return ref.layernorm(x2d, g, b)


def _attention(cfg, q, k, v):
    if cfg.use_pallas:
        return attn_k.causal_attention(q, k, v)
    return ref.causal_attention(q, k, v)


def forward(cfg: ModelConfig, params: List[jax.Array], tokens: jax.Array) -> jax.Array:
    """Logits ``[batch, seq, vocab]`` for ``tokens [batch, seq]`` (s32)."""
    b, s = tokens.shape
    d, h = cfg.d_model, cfg.n_head
    it = iter(params)
    tok_emb = next(it)
    pos_emb = next(it)
    x = tok_emb[tokens] + pos_emb[None, :s, :]  # [B, S, D]
    for _ in range(cfg.n_layer):
        ln1g, ln1b = next(it), next(it)
        wqkv = next(it)
        wo = next(it)
        ln2g, ln2b = next(it), next(it)
        w1 = next(it)
        w2 = next(it)

        # -- attention sublayer ----------------------------------------
        xn = _layernorm(cfg, x.reshape(b * s, d), ln1g, ln1b).reshape(b, s, d)
        qkv = xn @ wqkv  # [B, S, 3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        # [B, S, D] → [B·H, S, hd]: the kernel's grid axis is heads.
        def heads(t):
            return t.reshape(b, s, h, cfg.head_dim).transpose(0, 2, 1, 3).reshape(
                b * h, s, cfg.head_dim
            )
        o = _attention(cfg, heads(q), heads(k), heads(v))
        o = o.reshape(b, h, s, cfg.head_dim).transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + o @ wo

        # -- MLP sublayer ------------------------------------------------
        xn = _layernorm(cfg, x.reshape(b * s, d), ln2g, ln2b).reshape(b, s, d)
        hdn = jax.nn.gelu(xn @ w1)
        x = x + hdn @ w2

    lnfg, lnfb = next(it), next(it)
    x = _layernorm(cfg, x.reshape(b * s, d), lnfg, lnfb).reshape(b, s, d)
    return x @ tok_emb.T  # tied embedding


def loss_fn(cfg: ModelConfig, params: List[jax.Array], tokens: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy (predict token t+1 from prefix ≤ t)."""
    logits = forward(cfg, params, tokens)[:, :-1, :]  # [B, S-1, V]
    targets = tokens[:, 1:]  # [B, S-1]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def train_step(cfg: ModelConfig, params: List[jax.Array], tokens: jax.Array):
    """One SGD step: returns ``(new_params, loss)``."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens))(params)
    new_params = [p - cfg.lr * g for p, g in zip(params, grads)]
    return new_params, loss


def train_step_flat(cfg: ModelConfig, *args):
    """AOT entry point: ``(param_0, …, param_{n-1}, tokens) →
    (param_0', …, param_{n-1}', loss)`` — the calling convention the rust
    ``Trainer`` implements."""
    params = list(args[:-1])
    tokens = args[-1]
    new_params, loss = train_step(cfg, params, tokens)
    return (*new_params, loss)


def make_jitted_step(cfg: ModelConfig):
    """Jitted train step for python-side tests/benches."""
    return jax.jit(functools.partial(train_step_flat, cfg))


def synthetic_batch(cfg: ModelConfig, key) -> jax.Array:
    """The learnable synthetic task shared with the rust Trainer: rows of
    the affine recurrence ``x_{t+1} = (5·x_t + 3) mod vocab``."""
    start = jax.random.randint(key, (cfg.batch, 1), 0, cfg.vocab)
    def step(x, _):
        nxt = (5 * x + 3) % cfg.vocab
        return nxt, nxt
    _, rest = jax.lax.scan(step, start, None, length=cfg.seq - 1)
    rest = jnp.swapaxes(rest[..., 0], 0, 1)  # [B, S-1]
    return jnp.concatenate([start, rest], axis=1).astype(jnp.int32)
