"""AOT pipeline tests: lowering to HLO text and the manifest contract."""

import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")


def test_probe_lowers_to_hlo_text():
    text = aot.lower_probe()
    assert "ENTRY" in text
    assert "f32[2,2]" in text


def test_train_step_lowers_for_test_sized_model():
    cfg = M.ModelConfig(
        name="unit", vocab=32, d_model=16, n_head=2, d_ff=32, n_layer=1,
        seq=8, batch=2,
    )
    text = aot.lower_train_step(cfg)
    assert "ENTRY" in text
    # Token input shape appears in the signature.
    assert "s32[2,8]" in text
    # The loss output (scalar f32) exists.
    assert "f32[]" in text


def test_variant_manifest_contract():
    cfg = M.TINY
    m = aot.variant_manifest(cfg, "train_step_tiny.hlo.txt")
    assert m["name"] == "tiny"
    assert m["tokens"]["shape"] == [cfg.batch, cfg.seq]
    assert m["tokens"]["dtype"] == "s32"
    assert len(m["params"]) == len(M.param_specs(cfg))
    # Manifest order must be exactly param_specs order (rust relies on it).
    for entry, (name, shape) in zip(m["params"], M.param_specs(cfg)):
        assert entry["name"] == name
        assert entry["shape"] == list(shape)
    assert m["config"]["param_count"] == M.param_count(cfg)
    # Must be JSON-serializable as-is.
    json.dumps(m)


def test_cli_writes_artifacts(tmp_path):
    """End-to-end: run aot as a module with a unit-sized variant injected."""
    # Use the real CLI but only the tiny variant to keep this test fast.
    out = tmp_path / "artifacts"
    import os

    proc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--variants", "tiny"],
        capture_output=True,
        text=True,
        # `compile` is importable from the python/ directory (one level up
        # from tests/), regardless of where pytest itself was launched.
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, proc.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["probe"] == "probe.hlo.txt"
    assert (out / "probe.hlo.txt").exists()
    names = [m["name"] for m in manifest["models"]]
    assert names == ["tiny"]
    hlo = (out / manifest["models"][0]["train_step"]).read_text()
    assert "ENTRY" in hlo
