"""L1 correctness: Pallas kernels (interpret=True) vs the pure-jnp oracle.

This is the core numeric signal for the whole stack — the same kernels
lower into the HLO artifact the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_k
from compile.kernels import layernorm as ln_k
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------- attention

TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5), jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("h,s,d", [(1, 8, 4), (2, 16, 8), (4, 64, 16), (2, 33, 8)])
def test_attention_matches_ref(dtype, h, s, d):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(h * 100 + s + d), 3)
    q, k, v = rand(k1, (h, s, d), dtype), rand(k2, (h, s, d), dtype), rand(k3, (h, s, d), dtype)
    got = attn_k.causal_attention(q, k, v)
    want = ref.causal_attention(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(1, 4),
    s=st.integers(2, 48),
    d=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref_hypothesis(h, s, d, seed):
    """Hypothesis sweep over shapes (the shipped models use S ≤ 128)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = rand(k1, (h, s, d), jnp.float32)
    k = rand(k2, (h, s, d), jnp.float32)
    v = rand(k3, (h, s, d), jnp.float32)
    got = attn_k.causal_attention(q, k, v)
    want = ref.causal_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_attention_is_causal():
    """Output at position t must not depend on tokens > t."""
    h, s, d = 2, 16, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (rand(kk, (h, s, d), jnp.float32) for kk in (k1, k2, k3))
    base = attn_k.causal_attention(q, k, v)
    # Perturb the future half of K and V.
    k2p = k.at[:, s // 2 :, :].set(99.0)
    v2p = v.at[:, s // 2 :, :].set(-99.0)
    pert = attn_k.causal_attention(q, k2p, v2p)
    np.testing.assert_allclose(
        np.asarray(base[:, : s // 2, :]), np.asarray(pert[:, : s // 2, :]),
        rtol=1e-6, atol=1e-6,
    )
    assert not np.allclose(np.asarray(base[:, -1, :]), np.asarray(pert[:, -1, :]))


def test_attention_first_position_is_v0():
    """Causal row 0 attends only to itself: out[0] == v[0]."""
    h, s, d = 1, 8, 4
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (rand(kk, (h, s, d), jnp.float32) for kk in (k1, k2, k3))
    out = attn_k.causal_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(v[:, 0]), rtol=1e-6)


def test_attention_custom_scale():
    h, s, d = 2, 12, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (rand(kk, (h, s, d), jnp.float32) for kk in (k1, k2, k3))
    got = attn_k.causal_attention(q, k, v, scale=0.25)
    want = ref.causal_attention(q, k, v, scale=0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_attention_grad_flows():
    """The kernel must be differentiable (it sits inside fwd+bwd AOT)."""
    h, s, d = 2, 8, 4
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (rand(kk, (h, s, d), jnp.float32) for kk in (k1, k2, k3))

    def f(q, k, v):
        return jnp.sum(attn_k.causal_attention(q, k, v) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.causal_attention(q, k, v) ** 2)

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_vmem_estimate_within_budget():
    """The shipped variants' per-instance working set must sit far below
    the ~16 MiB TPU VMEM budget (DESIGN.md §Perf)."""
    assert attn_k.vmem_bytes(s=64, d=32) < 1 << 20
    assert attn_k.vmem_bytes(s=128, d=32) < 2 << 20
    assert attn_k.vmem_bytes(s=256, d=64) < 4 << 20


# ---------------------------------------------------------------- layernorm

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows,dim", [(8, 16), (128, 64), (256, 32), (96, 48)])
def test_layernorm_matches_ref(dtype, rows, dim):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(rows + dim), 3)
    x = rand(k1, (rows, dim), dtype)
    g = rand(k2, (dim,), jnp.float32) + 1.0
    b = rand(k3, (dim,), jnp.float32)
    got = ln_k.layernorm(x, g, b)
    want = ref.layernorm(x, g, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([4, 16, 64, 100, 128]),
    dim=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_hypothesis(rows, dim, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(k1, (rows, dim), jnp.float32)
    g = rand(k2, (dim,), jnp.float32)
    b = rand(k3, (dim,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ln_k.layernorm(x, g, b)),
        np.asarray(ref.layernorm(x, g, b)),
        rtol=2e-5, atol=2e-5,
    )


def test_layernorm_output_is_normalized():
    x = jax.random.normal(jax.random.PRNGKey(5), (32, 64)) * 7 + 3
    y = np.asarray(ln_k.layernorm(x, jnp.ones(64), jnp.zeros(64)))
    np.testing.assert_allclose(y.mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(y.std(axis=-1), 1.0, atol=1e-2)


def test_layernorm_nonmultiple_rows_falls_back():
    """Row counts that do not divide the block still work (single tile)."""
    x = jax.random.normal(jax.random.PRNGKey(6), (37, 16))
    got = ln_k.layernorm(x, jnp.ones(16), jnp.zeros(16), block_rows=128)
    want = ref.layernorm(x, jnp.ones(16), jnp.zeros(16))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
