"""L2 correctness: the transformer model — shapes, gradients, learning,
and Pallas-vs-reference parity of the full train step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(
    name="test", vocab=64, d_model=32, n_head=2, d_ff=64, n_layer=2,
    seq=16, batch=4, lr=0.1,
)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_param_specs_cover_init(params):
    specs = M.param_specs(CFG)
    assert len(specs) == len(params)
    for (name, shape), p in zip(specs, params):
        assert p.shape == shape, name
    # 2 emb + 8/layer + 2 final.
    assert len(specs) == 2 + 8 * CFG.n_layer + 2


def test_param_count_matches(params):
    total = sum(int(np.prod(p.shape)) for p in params)
    assert M.param_count(CFG) == total


def test_forward_shapes(params):
    toks = M.synthetic_batch(CFG, jax.random.PRNGKey(1))
    assert toks.shape == (CFG.batch, CFG.seq)
    assert toks.dtype == jnp.int32
    logits = M.forward(CFG, params, toks)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform(params):
    """Random init ⇒ loss ≈ ln(vocab)."""
    toks = M.synthetic_batch(CFG, jax.random.PRNGKey(2))
    loss = float(M.loss_fn(CFG, params, toks))
    assert abs(loss - np.log(CFG.vocab)) < 0.5, loss


def test_loss_decreases_on_synthetic_task(params):
    """A few SGD steps on the affine-recurrence task must cut the loss —
    the same signal the live-mode loss curves show."""
    p = params
    key = jax.random.PRNGKey(3)
    step = M.make_jitted_step(CFG)
    losses = []
    for i in range(30):
        key, sub = jax.random.split(key)
        toks = M.synthetic_batch(CFG, sub)
        out = step(*p, toks)
        p, loss = list(out[:-1]), out[-1]
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses


def test_grads_finite(params):
    toks = M.synthetic_batch(CFG, jax.random.PRNGKey(4))
    grads = jax.grad(lambda p: M.loss_fn(CFG, p, toks))(params)
    for g in grads:
        assert bool(jnp.all(jnp.isfinite(g)))


def test_pallas_and_ref_paths_agree(params):
    """use_pallas=True vs False must produce the same loss and the same
    updated parameters (the kernels are drop-in)."""
    import dataclasses

    toks = M.synthetic_batch(CFG, jax.random.PRNGKey(5))
    cfg_ref = dataclasses.replace(CFG, use_pallas=False)
    newp_a, loss_a = M.train_step(CFG, params, toks)
    newp_b, loss_b = M.train_step(cfg_ref, params, toks)
    np.testing.assert_allclose(float(loss_a), float(loss_b), rtol=1e-5)
    for a, b in zip(newp_a, newp_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_train_step_flat_convention(params):
    """(params…, tokens) → (params…, loss): the AOT/rust contract."""
    toks = M.synthetic_batch(CFG, jax.random.PRNGKey(6))
    out = M.train_step_flat(CFG, *params, toks)
    assert len(out) == len(params) + 1
    assert out[-1].shape == ()
    for p, o in zip(params, out[:-1]):
        assert p.shape == o.shape


def test_synthetic_batch_follows_recurrence():
    toks = np.asarray(M.synthetic_batch(CFG, jax.random.PRNGKey(7)))
    for row in toks:
        for t in range(len(row) - 1):
            assert row[t + 1] == (5 * row[t] + 3) % CFG.vocab


def test_tiny_and_small_configs_are_consistent():
    for cfg in (M.TINY, M.SMALL):
        assert cfg.d_model % cfg.n_head == 0
        assert M.param_count(cfg) > 0
    assert M.param_count(M.SMALL) > M.param_count(M.TINY)
