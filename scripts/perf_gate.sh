#!/usr/bin/env bash
# Perf-regression gate (CI `perf-gate` job).
#
# Compares the just-measured BENCH_scale.json against the committed
# BENCH_baseline.json and fails on a >15% jobs/sec regression, then pins
# the allocation-free hot-path guarantee via BENCH_hotpath.json
# (`steady_state_allocs_per_op` must be exactly 0).
#
# Blessing a new baseline (after an intentional perf change, measured on
# the CI runner class):
#
#     cp BENCH_scale.json BENCH_baseline.json
#     git add BENCH_baseline.json && git commit
#
# The committed baseline may be a conservative *floor* rather than a real
# measurement (marked "is_floor": true) — e.g. when seeded on a machine
# class different from CI. The gate works the same either way; blessing
# with a real CI measurement tightens it.
#
# The plan-path microbenches (`plan_blocked_te_*`) are additionally gated
# against BENCH_hotpath_baseline.json ns/op ceilings; bless those with
#
#     cp BENCH_hotpath.json BENCH_hotpath_baseline.json
#     git add BENCH_hotpath_baseline.json && git commit
#
# Usage: scripts/perf_gate.sh [baseline.json] [scale.json] [hotpath.json] [hotpath-baseline.json]
set -euo pipefail

BASELINE=${1:-BENCH_baseline.json}
SCALE=${2:-BENCH_scale.json}
HOTPATH=${3:-BENCH_hotpath.json}
HOTPATH_BASELINE=${4:-BENCH_hotpath_baseline.json}
TOLERANCE=0.85 # fail below baseline × this
OP_TOLERANCE=1.25 # per-op ns ceiling: baseline × this

for f in "$BASELINE" "$SCALE" "$HOTPATH"; do
  if [ ! -f "$f" ]; then
    echo "perf-gate: missing $f" >&2
    exit 1
  fi
done

measured=$(jq -er '.jobs_per_sec' "$SCALE")
cells=$(jq -r '.cells // 1' "$SCALE")
floor=$(jq -er '.jobs_per_sec' "$BASELINE")
is_floor=$(jq -r '.is_floor // false' "$BASELINE")
pre_pr=$(jq -r '.pre_pr_jobs_per_sec // empty' "$BASELINE")

if [ "$cells" != "1" ]; then
  echo "perf-gate: $SCALE was produced with FITGPP_CELLS=$cells;" \
    "the gate compares single-cell throughput only" >&2
  exit 1
fi

echo "perf-gate: measured ${measured} jobs/sec vs baseline ${floor} (floor marker: ${is_floor})"

if ! jq -en --argjson m "$measured" --argjson f "$floor" --argjson t "$TOLERANCE" \
  '$m >= $f * $t' >/dev/null; then
  echo "perf-gate: FAIL — ${measured} jobs/sec is below ${TOLERANCE} × baseline ${floor}" >&2
  echo "perf-gate: if this regression is intentional, bless a new baseline:" >&2
  echo "perf-gate:     cp $SCALE $BASELINE && git add $BASELINE" >&2
  exit 1
fi

if [ -n "$pre_pr" ]; then
  speedup=$(jq -n --argjson m "$measured" --argjson p "$pre_pr" '$m / $p')
  echo "perf-gate: speedup vs pre-raw-speed-campaign baseline (${pre_pr} jobs/sec): ${speedup}x"
fi

allocs=$(jq -er '.steady_state_allocs_per_op' "$HOTPATH")
if ! jq -en --argjson a "$allocs" '$a == 0' >/dev/null; then
  echo "perf-gate: FAIL — steady-state hot path allocates (${allocs} allocs/op, expected 0)" >&2
  echo "perf-gate: see the per-op breakdown in $HOTPATH (.ops)" >&2
  exit 1
fi
echo "perf-gate: steady-state hot path is allocation-free (0 allocs/op)"

# Plan-path gates: the preemption-planning ops must stay allocation-free
# (the victim index + plan scratch guarantee) and within the blessed ns/op
# ceiling. The committed ceiling may be a conservative floor, like the
# throughput baseline.
for op in plan_blocked_te_256 plan_blocked_te_4096; do
  op_allocs=$(jq -er ".ops[\"$op\"].allocs_per_op" "$HOTPATH")
  if ! jq -en --argjson a "$op_allocs" '$a == 0' >/dev/null; then
    echo "perf-gate: FAIL — $op allocates (${op_allocs} allocs/op, expected 0)" >&2
    exit 1
  fi
  if [ -f "$HOTPATH_BASELINE" ]; then
    op_ns=$(jq -er ".ops[\"$op\"].ns_per_op" "$HOTPATH")
    op_floor=$(jq -r ".ops[\"$op\"].ns_per_op // empty" "$HOTPATH_BASELINE")
    if [ -n "$op_floor" ]; then
      if ! jq -en --argjson m "$op_ns" --argjson f "$op_floor" --argjson t "$OP_TOLERANCE" \
        '$m <= $f * $t' >/dev/null; then
        echo "perf-gate: FAIL — $op at ${op_ns} ns/op exceeds ${OP_TOLERANCE} × baseline ${op_floor}" >&2
        echo "perf-gate: if intentional: cp $HOTPATH $HOTPATH_BASELINE && git add $HOTPATH_BASELINE" >&2
        exit 1
      fi
      echo "perf-gate: $op ${op_ns} ns/op (ceiling ${op_floor} × ${OP_TOLERANCE}), 0 allocs/op"
    fi
  else
    echo "perf-gate: $op 0 allocs/op (no $HOTPATH_BASELINE — ns/op ceiling skipped)"
  fi
done
# Serve wire-throughput gates (optional: only when the serve bench ran).
# Bless with: cp BENCH_serve.json BENCH_serve_baseline.json
SERVE=${SERVE:-BENCH_serve.json}
SERVE_BASELINE=${SERVE_BASELINE:-BENCH_serve_baseline.json}
if [ -f "$SERVE" ] && [ -f "$SERVE_BASELINE" ]; then
  for key in commands_per_sec events_per_sec; do
    serve_m=$(jq -er ".$key" "$SERVE")
    serve_f=$(jq -er ".$key" "$SERVE_BASELINE")
    if ! jq -en --argjson m "$serve_m" --argjson f "$serve_f" --argjson t "$TOLERANCE" \
      '$m >= $f * $t' >/dev/null; then
      echo "perf-gate: FAIL — serve $key ${serve_m} is below ${TOLERANCE} × baseline ${serve_f}" >&2
      echo "perf-gate: if intentional: cp $SERVE $SERVE_BASELINE && git add $SERVE_BASELINE" >&2
      exit 1
    fi
    echo "perf-gate: serve $key ${serve_m} (floor ${serve_f})"
  done
  # Latency / stall ceilings (baseline × OP_TOLERANCE). Skipped per-key
  # when either file predates the field, so old baselines keep working.
  for key in ack_p50_us ack_p99_us snapshot_stall_ms; do
    serve_m=$(jq -r ".$key // empty" "$SERVE")
    serve_c=$(jq -r ".$key // empty" "$SERVE_BASELINE")
    if [ -z "$serve_m" ] || [ -z "$serve_c" ]; then
      echo "perf-gate: serve $key absent — ceiling skipped"
      continue
    fi
    if ! jq -en --argjson m "$serve_m" --argjson c "$serve_c" --argjson t "$OP_TOLERANCE" \
      '$m <= $c * $t' >/dev/null; then
      echo "perf-gate: FAIL — serve $key ${serve_m} exceeds ${OP_TOLERANCE} × baseline ${serve_c}" >&2
      echo "perf-gate: if intentional: cp $SERVE $SERVE_BASELINE && git add $SERVE_BASELINE" >&2
      exit 1
    fi
    echo "perf-gate: serve $key ${serve_m} (ceiling ${serve_c} × ${OP_TOLERANCE})"
  done
  # The wire encode path must be allocation-free, like the sim hot path.
  serve_allocs=$(jq -r '.steady_state_allocs_per_op // empty' "$SERVE")
  if [ -n "$serve_allocs" ]; then
    if ! jq -en --argjson a "$serve_allocs" '$a == 0' >/dev/null; then
      echo "perf-gate: FAIL — serve encode path allocates (${serve_allocs} allocs/op, expected 0)" >&2
      exit 1
    fi
    echo "perf-gate: serve encode path is allocation-free (0 allocs/op)"
  fi
elif [ -f "$SERVE_BASELINE" ]; then
  echo "perf-gate: $SERVE not present — serve wire-throughput gate skipped"
fi
echo "perf-gate: OK"
