//! Vendored minimal reimplementation of the `anyhow` error-handling API.
//!
//! The build image is offline (no crates.io), so the repository carries the
//! small subset of anyhow it actually uses:
//!
//! * [`Error`] — an opaque error value holding a context chain,
//! * [`Result`] — `Result<T, Error>` with a defaulted error type,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * [`anyhow!`] and [`bail!`] macros.
//!
//! Formatting matches anyhow's conventions: `{}` prints the outermost
//! message, `{:#}` prints the whole chain joined with `": "`, and `{:?}`
//! prints the message followed by a `Caused by:` list.

use std::error::Error as StdError;
use std::fmt;

/// An opaque error: a chain of messages, outermost context first, root
/// cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the message chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result<T, anyhow::Error>` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error carried by a `Result` or the `None` case of
/// an `Option`.
pub trait Context<T> {
    /// Wrap the error with `context`.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

// Same coherence pattern as anyhow itself: a blanket impl over std errors
// plus a direct impl for `Error` (which deliberately does NOT implement
// `std::error::Error`, so the impls cannot overlap).
impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err()).context("reading config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_and_macros() {
        let r: Result<u32> = None.context("nothing there");
        assert_eq!(format!("{}", r.unwrap_err()), "nothing there");
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(format!("{e}"), "bad value 3");
        fn fails() -> Result<()> {
            bail!("boom {}", 7);
        }
        assert_eq!(format!("{}", fails().unwrap_err()), "boom 7");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("root"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root");
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().count(), 2);
    }
}
